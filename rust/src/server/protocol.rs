//! Wire-format mapping: JSON objects <-> engine request/output types.
//!
//! Two protocol versions share one parser and one encoder:
//!
//! * **v1** (legacy, no `"v"` field): one request line, one response
//!   line. Still the shape every pre-existing client speaks.
//! * **v2** (`{"v":2,"op":...}`): the same ops plus `cancel`, and the
//!   streaming extensions on `generate` (`stream`, `preview_every`,
//!   `strength`/`init_latent`, `variations`). A streamed generate
//!   answers with typed *event frames* — `{"v":2,"event":"queued"|
//!   "progress"|"preview"|"done"|"error","id":...}` — instead of a
//!   single response. The `done`/`error` events are exactly
//!   [`render_output`]/[`render_failure`] plus the envelope tag, so the
//!   non-streamed v2 response stays byte-identical to v1.
//!
//! [`parse_frame`] is the single entry point: it sniffs the version
//! (absent `"v"` means v1) and routes both through the same
//! [`ServerOp`] enum; v2-only fields on a v1 frame are protocol errors,
//! not silent drops.

use std::sync::Arc;

use crate::engine::{GenerationOutput, GenerationRequest, InitImage};
use crate::error::{Error, Result};
use crate::guidance::{AdaptiveConfig, GuidanceSchedule, GuidanceStrategy, WindowPosition};
use crate::image::{encode_png, RgbImage};
use crate::json::Value;
use crate::qos::{Priority, QosMeta};
use crate::scheduler::SchedulerKind;

use super::base64::b64encode;

/// Fields a v1 frame must not carry — the streaming surface is v2-only
/// so a legacy client gets a typed rejection instead of a silently
/// ignored knob.
const V2_ONLY_FIELDS: [&str; 5] =
    ["stream", "preview_every", "strength", "init_latent", "variations"];

/// A parsed `generate` operation.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub request: GenerationRequest,
    /// Serving metadata: deadline + priority class (QoS admission).
    pub meta: QosMeta,
    /// Did the payload carry an explicit `adaptive` field? A client's
    /// explicit `false` must override a server-side adaptive default,
    /// which an absent field must not.
    pub adaptive_set: bool,
    /// Did the payload carry an explicit schedule field
    /// (`window_fraction` / `window_position` / `segments` / `interval`
    /// / `cadence`)? Server-side guidance defaults must not override a
    /// client's deliberate schedule experiment.
    pub schedule_set: bool,
    /// Did the payload carry an explicit `strategy` field?
    pub strategy_set: bool,
    /// Include the PNG (base64) in the response.
    pub return_image: bool,
    /// Include the raw final latent in the response.
    pub return_latent: bool,
    /// v2: stream typed event frames (`queued`/`progress`/`preview`/
    /// `done`) instead of a single response line.
    pub stream: bool,
    /// v2: push a `preview` event (intermediate latent decoded to PNG)
    /// every K denoising steps. 0 = progress events only. Requires
    /// `stream`.
    pub preview_every: usize,
    /// v2: fan this request out into N seed variations sharing one
    /// compiled guidance plan. 1 = no fan-out.
    pub variations: usize,
}

/// Parse a v1 `{"op":"generate", ...}` JSON object (legacy adapter —
/// rejects the v2-only streaming fields).
pub fn parse_request(v: &Value) -> Result<ServerRequest> {
    parse_request_versioned(v, 1)
}

/// Parse a `generate` payload under the given protocol version.
pub fn parse_request_versioned(v: &Value, version: u8) -> Result<ServerRequest> {
    let prompt = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Protocol("generate: missing prompt".into()))?;
    let mut req = GenerationRequest::new(prompt);
    if let Some(steps) = v.get("steps") {
        req.steps = steps
            .as_usize()
            .ok_or_else(|| Error::Protocol("steps must be a positive integer".into()))?;
    }
    if let Some(gs) = v.get("guidance_scale") {
        req.guidance_scale =
            gs.as_f64().ok_or_else(|| Error::Protocol("guidance_scale must be a number".into()))?
                as f32;
    }
    if let Some(seed) = v.get("seed") {
        let raw =
            seed.as_i64().ok_or_else(|| Error::Protocol("seed must be an integer".into()))?;
        // shared validation with TOML/CLI/workload: a negative seed is
        // a protocol error, not a silent two's-complement wrap
        req.seed = crate::config::seed_from_i64(raw).map_err(Error::Protocol)?;
    }
    if let Some(s) = v.get("scheduler") {
        req.scheduler = SchedulerKind::parse(
            s.as_str().ok_or_else(|| Error::Protocol("scheduler must be a string".into()))?,
        )?;
    }
    // ---- the schedule surface: type extraction only — mutual
    // exclusion and per-kind dispatch live in
    // GuidanceSchedule::from_parts, shared with the TOML and CLI
    // surfaces
    let position = match v.get("window_position") {
        Some(p) => Some(
            WindowPosition::parse(p.as_str().ok_or_else(|| {
                Error::Protocol("window_position must be a string".into())
            })?)
            .map_err(|e| Error::Protocol(e.to_string()))?,
        ),
        None => None,
    };
    // window_position alone still selects a (zero-width) window so a
    // typo'd combination is validated instead of silently ignored
    let window = match v.get("window_fraction") {
        Some(f) => {
            let fraction = f
                .as_f64()
                .ok_or_else(|| Error::Protocol("window_fraction must be a number".into()))?;
            Some((fraction, position.unwrap_or(WindowPosition::Last)))
        }
        None => position.map(|p| (0.0, p)),
    };
    let segments = match v.get("segments") {
        Some(s) => Some(
            s.as_str()
                .ok_or_else(|| Error::Protocol("segments must be a string".into()))?,
        ),
        None => None,
    };
    let interval = match v.get("interval") {
        Some(s) => Some(
            s.as_str()
                .ok_or_else(|| Error::Protocol("interval must be a string".into()))?,
        ),
        None => None,
    };
    let cadence = match v.get("cadence") {
        Some(s) => Some(s.as_usize().ok_or_else(|| {
            Error::Protocol("cadence must be a positive integer".into())
        })?),
        None => None,
    };
    let schedule_set =
        window.is_some() || segments.is_some() || interval.is_some() || cadence.is_some();
    if let Some(s) = GuidanceSchedule::from_parts(window, segments, interval, cadence)
        .map_err(|e| Error::Protocol(e.to_string()))?
    {
        req.schedule = s;
    }
    let strategy_set = v.get("strategy").is_some();
    if let Some(s) = v.get("strategy") {
        let name = s
            .as_str()
            .ok_or_else(|| Error::Protocol("strategy must be a string".into()))?;
        let refresh = match v.get("refresh_every") {
            Some(r) => r.as_usize().ok_or_else(|| {
                Error::Protocol("refresh_every must be a non-negative integer".into())
            })?,
            None => 0,
        };
        req.strategy = GuidanceStrategy::parse(name, refresh)?;
    } else if v.get("refresh_every").is_some() {
        return Err(Error::Protocol("refresh_every requires a strategy field".into()));
    }
    // ---- the adaptive (online) skip controller: `"adaptive": true`
    // enables it with defaults, `adaptive_*` fields refine it; knobs
    // without the switch are a protocol error (mirrors refresh_every)
    let adaptive_knobs = [
        "adaptive_threshold",
        "adaptive_patience",
        "adaptive_min_dual_fraction",
        "adaptive_probe_every",
    ];
    let adaptive_set = v.get("adaptive").is_some();
    let enabled = match v.get("adaptive") {
        Some(b) => b
            .as_bool()
            .ok_or_else(|| Error::Protocol("adaptive must be a boolean".into()))?,
        None => false,
    };
    if enabled {
        let mut a = AdaptiveConfig::default();
        if let Some(t) = v.get("adaptive_threshold") {
            a.threshold = t
                .as_f64()
                .ok_or_else(|| Error::Protocol("adaptive_threshold must be a number".into()))?;
        }
        if let Some(p) = v.get("adaptive_patience") {
            a.patience = p
                .as_usize()
                .ok_or_else(|| Error::Protocol("adaptive_patience must be an integer".into()))?;
        }
        if let Some(f) = v.get("adaptive_min_dual_fraction") {
            a.min_dual_fraction = f.as_f64().ok_or_else(|| {
                Error::Protocol("adaptive_min_dual_fraction must be a number".into())
            })?;
        }
        if let Some(p) = v.get("adaptive_probe_every") {
            a.probe_every = p.as_usize().ok_or_else(|| {
                Error::Protocol("adaptive_probe_every must be an integer".into())
            })?;
        }
        a.validate().map_err(|e| Error::Protocol(e.to_string()))?;
        req.adaptive = Some(a);
    } else if let Some(orphan) = adaptive_knobs.iter().find(|&&k| v.get(k).is_some()) {
        return Err(Error::Protocol(format!("{orphan} requires \"adaptive\": true")));
    }
    let mut meta = QosMeta::default();
    if let Some(d) = v.get("deadline_ms") {
        let ms = d
            .as_f64()
            .ok_or_else(|| Error::Protocol("deadline_ms must be a number".into()))?;
        // the upper bound keeps Duration::from_secs_f64 panic-free on
        // hostile input — a connection must never die to a bad field
        if !ms.is_finite() || ms <= 0.0 || ms > crate::qos::MAX_DEADLINE_MS {
            return Err(Error::Protocol(format!(
                "deadline_ms {ms} outside (0, {}]",
                crate::qos::MAX_DEADLINE_MS
            )));
        }
        meta.deadline = Some(std::time::Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(p) = v.get("priority") {
        meta.priority = Priority::parse(
            p.as_str().ok_or_else(|| Error::Protocol("priority must be a string".into()))?,
        )?;
    }
    if let Some(p) = v.get("planner") {
        // "planner": false opts this request out of frontier plan search
        // (DESIGN.md §16) — it degrades via the legacy analytic actuator
        meta.planner_opt_out = !p
            .as_bool()
            .ok_or_else(|| Error::Protocol("planner must be a boolean".into()))?;
    }
    let return_image = v.get("return_image").and_then(Value::as_bool).unwrap_or(false);
    let return_latent = v.get("return_latent").and_then(Value::as_bool).unwrap_or(false);
    req.decode = return_image || req.decode;
    // ---- the v2 streaming surface. A v1 frame carrying any of these
    // is a protocol error: silently ignoring `stream` would leave the
    // client waiting on event frames that never come.
    if version < 2 {
        if let Some(f) = V2_ONLY_FIELDS.iter().find(|&&k| v.get(k).is_some()) {
            return Err(Error::Protocol(format!("{f} requires protocol v2 ({{\"v\":2}})")));
        }
    }
    let stream = match v.get("stream") {
        Some(b) => b
            .as_bool()
            .ok_or_else(|| Error::Protocol("stream must be a boolean".into()))?,
        None => false,
    };
    let preview_every = match v.get("preview_every") {
        Some(p) => {
            let every = p.as_usize().ok_or_else(|| {
                Error::Protocol("preview_every must be a non-negative integer".into())
            })?;
            // orphan knob without the switch, mirrors refresh_every
            if !stream {
                return Err(Error::Protocol(
                    "preview_every requires \"stream\": true".into(),
                ));
            }
            every
        }
        None => 0,
    };
    if let Some(s) = v.get("strength") {
        let strength = s
            .as_f64()
            .ok_or_else(|| Error::Protocol("strength must be a number".into()))?;
        let latent = match v.get("init_latent") {
            Some(arr) => {
                let items = arr.as_arr().ok_or_else(|| {
                    Error::Protocol("init_latent must be an array of numbers".into())
                })?;
                let mut lat = Vec::with_capacity(items.len());
                for it in items {
                    lat.push(it.as_f64().ok_or_else(|| {
                        Error::Protocol("init_latent must be an array of numbers".into())
                    })? as f32);
                }
                Some(Arc::new(lat))
            }
            None => None, // seed-derived synthetic init latent
        };
        req.init = Some(InitImage { latent, strength });
    } else if v.get("init_latent").is_some() {
        return Err(Error::Protocol("init_latent requires a strength field".into()));
    }
    let variations = match v.get("variations") {
        Some(n) => {
            let n = n.as_usize().ok_or_else(|| {
                Error::Protocol("variations must be a positive integer".into())
            })?;
            if n == 0 {
                return Err(Error::Protocol("variations must be >= 1".into()));
            }
            n
        }
        None => 1,
    };
    req.validate()?;
    Ok(ServerRequest {
        request: req,
        meta,
        adaptive_set,
        schedule_set,
        strategy_set,
        return_image,
        return_latent,
        stream,
        preview_every,
        variations,
    })
}

/// One parsed wire frame: the sniffed protocol version, the client's
/// correlation id, and the operation — v1 and v2 both land here.
#[derive(Debug)]
pub struct Frame {
    pub version: u8,
    pub id: Option<i64>,
    pub op: ServerOp,
}

/// Every operation either protocol version can carry. `Cancel` is
/// v2-only; `Generate` carries the version-gated streaming fields.
#[derive(Debug)]
pub enum ServerOp {
    Ping,
    Stats,
    Metrics,
    /// `trace: None` lists recent span ids; `Some(id)` fetches one span.
    Trace { trace: Option<i64> },
    Shutdown,
    Generate(Box<ServerRequest>),
    /// v2: abort the in-flight `generate` whose frame `id` was `target`,
    /// freeing its continuous-batch slots as admission headroom.
    Cancel { target: i64 },
}

/// Parse one wire frame. An absent `"v"` field means v1 (every legacy
/// client); `"v":1` and `"v":2` are explicit; anything else is a
/// protocol error so version skew fails loudly.
pub fn parse_frame(v: &Value) -> Result<Frame> {
    let version = match v.get("v") {
        None => 1,
        Some(val) => match val.as_i64() {
            Some(1) => 1,
            Some(2) => 2,
            Some(n) => {
                return Err(Error::Protocol(format!("unsupported protocol version {n}")))
            }
            None => return Err(Error::Protocol("v must be an integer".into())),
        },
    };
    let id = v.get("id").and_then(Value::as_i64);
    let op = match v.get("op").and_then(Value::as_str) {
        Some("ping") => ServerOp::Ping,
        Some("stats") => ServerOp::Stats,
        Some("metrics") => ServerOp::Metrics,
        Some("shutdown") => ServerOp::Shutdown,
        // `trace` names the span — never `id`, which clients use for
        // request/response correlation
        Some("trace") => ServerOp::Trace { trace: v.get("trace").and_then(Value::as_i64) },
        Some("generate") => {
            ServerOp::Generate(Box::new(parse_request_versioned(v, version)?))
        }
        Some("cancel") if version >= 2 => {
            let target = v
                .get("target")
                .and_then(Value::as_i64)
                .ok_or_else(|| Error::Protocol("cancel: missing target".into()))?;
            ServerOp::Cancel { target }
        }
        Some("cancel") => {
            return Err(Error::Protocol("cancel requires protocol v2 ({\"v\":2})".into()))
        }
        Some(other) => return Err(Error::Protocol(format!("unknown op {other:?}"))),
        None => return Err(Error::Protocol("missing op".into())),
    };
    Ok(Frame { version, id, op })
}

/// Render a generation failure, giving QoS outcomes their structured
/// 429/503/504-style shape so clients can branch without parsing
/// message strings.
pub fn render_failure(id: Option<i64>, e: &Error) -> Value {
    let mut v = Value::obj().with("ok", false).with("error", e.to_string());
    // qos_code() owns the error -> HTTP-code mapping; only the shape
    // flags are decided here
    if let Some(code) = e.qos_code() {
        v = v.with("code", code as i64);
    }
    match e {
        Error::Rejected { reason, .. } => {
            v = v.with("rejected", true).with("reason", reason.as_str());
        }
        Error::DeadlineExceeded(_) => {
            v = v.with("deadline_exceeded", true);
        }
        _ => {}
    }
    if let Some(id) = id {
        v = v.with("id", id);
    }
    v
}

/// Render a generation result for the wire.
pub fn render_output(id: Option<i64>, sr: &ServerRequest, out: &GenerationOutput) -> Value {
    let mut v = Value::obj()
        .with("ok", true)
        .with("wall_ms", out.wall_ms)
        .with("unet_evals", out.unet_evals as i64)
        .with("steps", out.steps as i64)
        // from the output, not sr: QoS admission may have rewritten the
        // request's strategy/schedule after parsing
        .with("strategy", out.strategy.name())
        // the executed plan summary — the same IR the eval-count
        // invariant audits, so clients can see exactly what ran
        .with("plan", out.plan_summary.as_str())
        .with("unet_cond_ms", out.breakdown.unet_cond_ms)
        .with("unet_uncond_ms", out.breakdown.unet_uncond_ms)
        .with("combine_ms", out.breakdown.combine_ms)
        .with("scheduler_ms", out.breakdown.scheduler_ms);
    if let Some(id) = id {
        v = v.with("id", id);
    }
    if sr.return_image {
        if let Some(img) = &out.image {
            if let Ok(png) = encode_png(img) {
                v = v
                    .with("png_b64", b64encode(&png))
                    .with("width", img.width as i64)
                    .with("height", img.height as i64);
            }
        }
    }
    if sr.return_latent {
        let latent: Vec<Value> = out.latent.iter().map(|&f| Value::float(f as f64)).collect();
        v = v.with("latent", Value::Arr(latent));
    }
    v
}

// ---- v2 event frames. A streamed generate answers with a sequence of
// these instead of one response line; `done`/`error` are the v1
// encoders plus the envelope tag, so the payload a v2 client unwraps is
// byte-identical to what a v1 client would have received.

/// Stamp the v2 event envelope onto an encoded payload object.
fn tag_event(mut v: Value, event: &str) -> Value {
    if let Value::Obj(m) = &mut v {
        m.insert("v".into(), Value::int(2));
        m.insert("event".into(), Value::str(event));
    }
    v
}

/// `queued`: the streamed generate was admitted; event frames follow.
pub fn event_queued(id: Option<i64>) -> Value {
    tag_event(ok_event(id), "queued")
}

/// `progress`: the sample finished denoising step `step` of `steps`.
pub fn event_progress(id: Option<i64>, step: usize, steps: usize) -> Value {
    tag_event(ok_event(id), "progress")
        .with("step", step as i64)
        .with("steps", steps as i64)
}

/// `preview`: an intermediate latent decoded to PNG at step `step`.
pub fn event_preview(id: Option<i64>, step: usize, img: &RgbImage) -> Result<Value> {
    let png = encode_png(img)?;
    Ok(tag_event(ok_event(id), "preview")
        .with("step", step as i64)
        .with("png_b64", b64encode(&png))
        .with("width", img.width as i64)
        .with("height", img.height as i64))
}

/// `done`: the full [`render_output`] payload under the event envelope.
pub fn event_done(id: Option<i64>, sr: &ServerRequest, out: &GenerationOutput) -> Value {
    tag_event(render_output(id, sr, out), "done")
}

/// `error`: the full [`render_failure`] payload under the event
/// envelope — cancellation surfaces here as its structured 499 shape.
pub fn event_error(id: Option<i64>, e: &Error) -> Value {
    tag_event(render_failure(id, e), "error")
}

fn ok_event(id: Option<i64>) -> Value {
    let v = Value::obj().with("ok", true);
    match id {
        Some(id) => v.with("id", id),
        None => v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::WindowSpec;
    use crate::json;
    use crate::metrics::StepBreakdown;

    fn parse(s: &str) -> Result<ServerRequest> {
        parse_request(&json::from_str(s).unwrap())
    }

    fn parse2(s: &str) -> Result<ServerRequest> {
        parse_request_versioned(&json::from_str(s).unwrap(), 2)
    }

    fn frame(s: &str) -> Result<Frame> {
        parse_frame(&json::from_str(s).unwrap())
    }

    #[test]
    fn full_request_parses() {
        let sr = parse(
            r#"{"op":"generate","prompt":"a cat","steps":25,"guidance_scale":9.6,
               "seed":3,"scheduler":"ddim","window_fraction":0.4,
               "window_position":"last","return_image":true}"#,
        )
        .unwrap();
        assert_eq!(sr.request.prompt, "a cat");
        assert_eq!(sr.request.steps, 25);
        assert_eq!(sr.request.guidance_scale, 9.6);
        assert_eq!(sr.request.seed, 3);
        assert_eq!(sr.request.scheduler, SchedulerKind::Ddim);
        assert_eq!(sr.request.schedule, GuidanceSchedule::Window(WindowSpec::last(0.4)));
        assert!(sr.return_image);
        assert!(!sr.return_latent);
    }

    #[test]
    fn defaults_applied() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.steps, 50);
        assert_eq!(sr.request.guidance_scale, 7.5);
        assert_eq!(sr.request.schedule, GuidanceSchedule::none());
        assert_eq!(sr.request.adaptive, None);
    }

    #[test]
    fn schedule_fields_parse() {
        let sr = parse(r#"{"op":"generate","prompt":"x","interval":"0.25-0.75"}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 });
        let sr = parse(r#"{"op":"generate","prompt":"x","cadence":4}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Cadence { every: 4 });
        let sr =
            parse(r#"{"op":"generate","prompt":"x","segments":"0.0-0.2,0.8-1.0"}"#).unwrap();
        assert!(matches!(sr.request.schedule, GuidanceSchedule::Segments(ref s) if s.len() == 2));
        // offset placements round-trip through the shared parser
        let sr = parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.25,
               "window_position":"offset(0.5)"}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.schedule,
            GuidanceSchedule::Window(WindowSpec::at_offset(0.5, 0.25))
        );
        // schedule_set records whether any schedule field was explicit
        assert!(sr.schedule_set);
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":4}"#).unwrap().schedule_set);
        assert!(!parse(r#"{"op":"generate","prompt":"x"}"#).unwrap().schedule_set);
        // schedule fields are mutually exclusive
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":4,"interval":"0.2-0.8"}"#)
            .is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.2,"cadence":4}"#
        )
        .is_err());
        // invalid values are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":0}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","interval":"0.8-0.2"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","segments":7}"#).is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.2,
               "window_position":"offset(2.0)"}"#
        )
        .is_err());
        // window_position alone is validated, not silently dropped
        assert!(parse(r#"{"op":"generate","prompt":"x","window_position":"bogus"}"#).is_err());
        let sr = parse(r#"{"op":"generate","prompt":"x","window_position":"first"}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Window(WindowSpec::first(0.0)));
        assert!(sr.schedule_set);
    }

    #[test]
    fn adaptive_fields_parse() {
        let sr = parse(r#"{"op":"generate","prompt":"x","adaptive":true}"#).unwrap();
        assert_eq!(sr.request.adaptive, Some(AdaptiveConfig::default()));
        let sr = parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_threshold":0.1,
               "adaptive_patience":3,"adaptive_min_dual_fraction":0.4,
               "adaptive_probe_every":6}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.adaptive,
            Some(AdaptiveConfig {
                threshold: 0.1,
                patience: 3,
                min_dual_fraction: 0.4,
                probe_every: 6
            })
        );
        // explicit off — adaptive_set records the client's explicit
        // choice so a server-side adaptive default cannot override it
        let sr = parse(r#"{"op":"generate","prompt":"x","adaptive":false}"#).unwrap();
        assert_eq!(sr.request.adaptive, None);
        assert!(sr.adaptive_set);
        assert!(!parse(r#"{"op":"generate","prompt":"x"}"#).unwrap().adaptive_set);
        // adaptive + an explicit schedule is a conflict, not a silent
        // precedence rule (the engine would ignore the schedule)
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive":true,"cadence":4}"#).is_err());
        // orphan knobs and bad values are protocol errors
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive_threshold":0.1}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive":7}"#).is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_threshold":-1}"#
        )
        .is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_min_dual_fraction":2.0}"#
        )
        .is_err());
    }

    #[test]
    fn seed_round_trips_and_negatives_rejected() {
        // valid seeds round-trip exactly, including large ones
        let sr = parse(r#"{"op":"generate","prompt":"x","seed":0}"#).unwrap();
        assert_eq!(sr.request.seed, 0);
        let sr =
            parse(r#"{"op":"generate","prompt":"x","seed":9007199254740991}"#).unwrap();
        assert_eq!(sr.request.seed, 9007199254740991);
        // a negative seed used to wrap through `as u64` into a
        // valid-looking 18-quintillion seed; now it's a typed rejection
        let err = parse(r#"{"op":"generate","prompt":"x","seed":-1}"#).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err:?}");
        assert!(err.to_string().contains("seed must be >= 0"));
        assert!(parse(r#"{"op":"generate","prompt":"x","seed":"lucky"}"#).is_err());
    }

    #[test]
    fn invalid_requests_rejected() {
        assert!(parse(r#"{"op":"generate"}"#).is_err()); // no prompt
        assert!(parse(r#"{"op":"generate","prompt":"x","steps":-1}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","window_fraction":3.0}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","scheduler":"bogus"}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","window_fraction":0.2,"window_position":"bogus"}"#)
                .is_err()
        );
    }

    #[test]
    fn strategy_fields_parse() {
        use crate::guidance::ReuseKind;
        let sr = parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.3,
               "strategy":"hold","refresh_every":4}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        let sr = parse(r#"{"op":"generate","prompt":"x","strategy":"extrapolate"}"#).unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 }
        );
        // default stays the paper's drop-guidance mode
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.strategy, GuidanceStrategy::CondOnly);
        // bad fields are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":"warp"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":7}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","strategy":"hold","refresh_every":-1}"#)
                .is_err()
        );
        assert!(parse(r#"{"op":"generate","prompt":"x","refresh_every":2}"#).is_err());
    }

    #[test]
    fn qos_fields_parse() {
        let sr = parse(
            r#"{"op":"generate","prompt":"x","deadline_ms":250.5,"priority":"interactive"}"#,
        )
        .unwrap();
        assert!((sr.meta.deadline_ms().unwrap() - 250.5).abs() < 1e-9);
        assert_eq!(sr.meta.priority, crate::qos::Priority::Interactive);
        // defaults: no deadline, standard priority
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.meta, crate::qos::QosMeta::default());
    }

    #[test]
    fn planner_opt_out_parses() {
        // explicit false opts out of frontier plan search
        let sr = parse(r#"{"op":"generate","prompt":"x","planner":false}"#).unwrap();
        assert!(sr.meta.planner_opt_out);
        // explicit true and absent both leave the planner eligible
        let sr = parse(r#"{"op":"generate","prompt":"x","planner":true}"#).unwrap();
        assert!(!sr.meta.planner_opt_out);
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert!(!sr.meta.planner_opt_out);
        // type errors are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","planner":"off"}"#).is_err());
        // v2 frames carry it too
        let sr =
            parse2(r#"{"v":2,"op":"generate","prompt":"x","planner":false}"#).unwrap();
        assert!(sr.meta.planner_opt_out);
    }

    #[test]
    fn bad_qos_fields_rejected() {
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":-5}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":"soon"}"#).is_err());
        // overflow guard: a huge deadline is a protocol error, not a
        // Duration::from_secs_f64 panic killing the connection
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":1e30}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":"urgent"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":3}"#).is_err());
    }

    #[test]
    fn rejection_renders_structured() {
        let e = Error::Rejected {
            code: 429,
            reason: "queue full: depth 8 >= class limit 8".into(),
        };
        let v = render_failure(Some(4), &e);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("rejected").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("code").unwrap().as_i64(), Some(429));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(4));
        assert!(v.get("reason").unwrap().as_str().unwrap().contains("queue full"));

        let d = render_failure(None, &Error::DeadlineExceeded("expired in queue".into()));
        assert_eq!(d.get("deadline_exceeded").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("code").unwrap().as_i64(), Some(504));

        // ordinary errors keep the legacy shape
        let o = render_failure(None, &Error::Request("empty prompt".into()));
        assert!(o.get("code").is_none());
        assert!(o.get("error").unwrap().as_str().unwrap().contains("empty prompt"));
    }

    #[test]
    fn render_includes_metrics() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        let out = GenerationOutput {
            latent: vec![0.5, -0.5],
            image: None,
            wall_ms: 123.4,
            breakdown: StepBreakdown { unet_cond_ms: 100.0, ..Default::default() },
            unet_evals: 90,
            steps: 50,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "40D 10C".into(),
        };
        let v = render_output(Some(7), &sr, &out);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(7));
        assert_eq!(v.get("unet_evals").unwrap().as_i64(), Some(90));
        // the echoed strategy comes from the executed output, not the
        // parsed request (QoS admission may rewrite it)
        assert_eq!(v.get("strategy").unwrap().as_str(), Some("cond-only"));
        // the executed plan is echoed from the same IR the invariant audits
        assert_eq!(v.get("plan").unwrap().as_str(), Some("40D 10C"));
        assert!(v.get("png_b64").is_none());
        assert!(v.get("latent").is_none());
    }

    #[test]
    fn v1_rejects_v2_only_fields() {
        // the whole streaming surface is gated: a legacy client must
        // get a typed rejection, not a silently dropped knob
        for payload in [
            r#"{"op":"generate","prompt":"x","stream":true}"#,
            r#"{"op":"generate","prompt":"x","stream":true,"preview_every":5}"#,
            r#"{"op":"generate","prompt":"x","strength":0.5}"#,
            r#"{"op":"generate","prompt":"x","init_latent":[0.0]}"#,
            r#"{"op":"generate","prompt":"x","variations":4}"#,
        ] {
            let err = parse(payload).unwrap_err();
            assert!(err.to_string().contains("protocol v2"), "{payload}: {err}");
        }
        // and via the frame parser, an absent "v" means v1
        assert!(frame(r#"{"op":"generate","prompt":"x","stream":true}"#).is_err());
        assert!(frame(r#"{"v":2,"op":"generate","prompt":"x","stream":true}"#).is_ok());
    }

    #[test]
    fn v2_streaming_fields_parse() {
        let sr = parse2(
            r#"{"v":2,"op":"generate","prompt":"x","stream":true,"preview_every":5}"#,
        )
        .unwrap();
        assert!(sr.stream);
        assert_eq!(sr.preview_every, 5);
        assert_eq!(sr.variations, 1);
        // defaults: not streamed
        let sr = parse2(r#"{"v":2,"op":"generate","prompt":"x"}"#).unwrap();
        assert!(!sr.stream);
        assert_eq!(sr.preview_every, 0);
        // orphan knob: preview cadence without the stream switch
        let err =
            parse2(r#"{"v":2,"op":"generate","prompt":"x","preview_every":5}"#).unwrap_err();
        assert!(err.to_string().contains("stream"), "{err}");
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","stream":7}"#).is_err());
        assert!(parse2(
            r#"{"v":2,"op":"generate","prompt":"x","stream":true,"preview_every":-1}"#
        )
        .is_err());
    }

    #[test]
    fn v2_img2img_fields_parse() {
        // strength alone: synthetic seed-derived init latent
        let sr = parse2(r#"{"v":2,"op":"generate","prompt":"x","strength":0.4}"#).unwrap();
        let init = sr.request.init.as_ref().unwrap();
        assert_eq!(init.strength, 0.4);
        assert!(init.latent.is_none());
        assert_eq!(sr.request.executed_steps(), 20); // 50 * 0.4
        // explicit init latent rides along
        let sr = parse2(
            r#"{"v":2,"op":"generate","prompt":"x","strength":0.5,"init_latent":[0.5,-0.5]}"#,
        )
        .unwrap();
        let lat = sr.request.init.as_ref().unwrap().latent.as_ref().unwrap();
        assert_eq!(lat.as_slice(), &[0.5, -0.5]);
        // orphan: a latent without a strength is meaningless
        let err = parse2(r#"{"v":2,"op":"generate","prompt":"x","init_latent":[0.0]}"#)
            .unwrap_err();
        assert!(err.to_string().contains("strength"), "{err}");
        // engine validation still runs: strength outside (0, 1] rejected
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","strength":0.0}"#).is_err());
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","strength":1.5}"#).is_err());
        assert!(parse2(
            r#"{"v":2,"op":"generate","prompt":"x","strength":0.5,"init_latent":"big"}"#
        )
        .is_err());
    }

    #[test]
    fn v2_variations_parse() {
        let sr = parse2(r#"{"v":2,"op":"generate","prompt":"x","variations":4}"#).unwrap();
        assert_eq!(sr.variations, 4);
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","variations":0}"#).is_err());
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","variations":-2}"#).is_err());
        assert!(parse2(r#"{"v":2,"op":"generate","prompt":"x","variations":"n"}"#).is_err());
    }

    #[test]
    fn frame_parser_sniffs_versions() {
        let f = frame(r#"{"op":"ping"}"#).unwrap();
        assert_eq!(f.version, 1);
        assert!(matches!(f.op, ServerOp::Ping));
        let f = frame(r#"{"v":2,"op":"stats","id":7}"#).unwrap();
        assert_eq!(f.version, 2);
        assert_eq!(f.id, Some(7));
        assert!(matches!(f.op, ServerOp::Stats));
        // explicit v1 is legal; unknown versions fail loudly
        assert_eq!(frame(r#"{"v":1,"op":"ping"}"#).unwrap().version, 1);
        assert!(frame(r#"{"v":3,"op":"ping"}"#).is_err());
        assert!(frame(r#"{"v":"two","op":"ping"}"#).is_err());
        // the trace op keeps its span-vs-correlation-id split
        let f = frame(r#"{"v":2,"op":"trace","trace":9,"id":1}"#).unwrap();
        assert!(matches!(f.op, ServerOp::Trace { trace: Some(9) }));
        let f = frame(r#"{"op":"trace"}"#).unwrap();
        assert!(matches!(f.op, ServerOp::Trace { trace: None }));
        // op errors match the legacy dispatch messages
        assert!(frame(r#"{"op":"warp"}"#).unwrap_err().to_string().contains("unknown op"));
        assert!(frame(r#"{"x":1}"#).unwrap_err().to_string().contains("missing op"));
    }

    #[test]
    fn cancel_is_v2_only() {
        let f = frame(r#"{"v":2,"op":"cancel","target":12,"id":3}"#).unwrap();
        assert!(matches!(f.op, ServerOp::Cancel { target: 12 }));
        let err = frame(r#"{"op":"cancel","target":12}"#).unwrap_err();
        assert!(err.to_string().contains("protocol v2"), "{err}");
        assert!(frame(r#"{"v":2,"op":"cancel"}"#)
            .unwrap_err()
            .to_string()
            .contains("missing target"));
    }

    #[test]
    fn event_frames_wrap_the_v1_encoders() {
        let sr = parse2(r#"{"v":2,"op":"generate","prompt":"x","stream":true}"#).unwrap();
        let out = GenerationOutput {
            latent: vec![0.0],
            image: None,
            wall_ms: 5.0,
            breakdown: StepBreakdown::default(),
            unet_evals: 4,
            steps: 2,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "2D".into(),
        };
        // done == render_output + the envelope tag, nothing else: a v2
        // client stripping {v, event} sees the exact v1 payload bytes
        let done = event_done(Some(3), &sr, &out);
        assert_eq!(done.get("event").unwrap().as_str(), Some("done"));
        assert_eq!(done.get("v").unwrap().as_i64(), Some(2));
        let mut stripped = done.clone();
        if let Value::Obj(m) = &mut stripped {
            m.remove("v");
            m.remove("event");
        }
        assert_eq!(stripped.to_string(), render_output(Some(3), &sr, &out).to_string());
        // error == render_failure + tag, keeping the structured shape
        let e = Error::Cancelled("cancelled by client".into());
        let ev = event_error(Some(3), &e);
        assert_eq!(ev.get("event").unwrap().as_str(), Some("error"));
        assert_eq!(ev.get("code").unwrap().as_i64(), Some(499));
        // progress / queued shapes
        let p = event_progress(Some(1), 5, 50);
        assert_eq!(p.get("event").unwrap().as_str(), Some("progress"));
        assert_eq!(p.get("step").unwrap().as_i64(), Some(5));
        assert_eq!(p.get("steps").unwrap().as_i64(), Some(50));
        let q = event_queued(None);
        assert_eq!(q.get("event").unwrap().as_str(), Some("queued"));
        assert!(q.get("id").is_none());
    }

    #[test]
    fn render_latent_when_requested() {
        let mut sr = parse(r#"{"op":"generate","prompt":"x","return_latent":true}"#).unwrap();
        sr.return_latent = true;
        let out = GenerationOutput {
            latent: vec![1.0, 2.0],
            image: None,
            wall_ms: 1.0,
            breakdown: StepBreakdown::default(),
            unet_evals: 2,
            steps: 1,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "1D".into(),
        };
        let v = render_output(None, &sr, &out);
        let arr = v.get("latent").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_f64(), Some(1.0));
    }
}
