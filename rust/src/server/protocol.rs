//! Wire-format mapping: JSON objects <-> engine request/output types.

use crate::engine::{GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};
use crate::guidance::{AdaptiveConfig, GuidanceSchedule, GuidanceStrategy, WindowPosition};
use crate::image::encode_png;
use crate::json::Value;
use crate::qos::{Priority, QosMeta};
use crate::scheduler::SchedulerKind;

use super::base64::b64encode;

/// A parsed `generate` operation.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub request: GenerationRequest,
    /// Serving metadata: deadline + priority class (QoS admission).
    pub meta: QosMeta,
    /// Did the payload carry an explicit `adaptive` field? A client's
    /// explicit `false` must override a server-side adaptive default,
    /// which an absent field must not.
    pub adaptive_set: bool,
    /// Did the payload carry an explicit schedule field
    /// (`window_fraction` / `window_position` / `segments` / `interval`
    /// / `cadence`)? Server-side guidance defaults must not override a
    /// client's deliberate schedule experiment.
    pub schedule_set: bool,
    /// Did the payload carry an explicit `strategy` field?
    pub strategy_set: bool,
    /// Include the PNG (base64) in the response.
    pub return_image: bool,
    /// Include the raw final latent in the response.
    pub return_latent: bool,
}

/// Parse a `{"op":"generate", ...}` JSON object.
pub fn parse_request(v: &Value) -> Result<ServerRequest> {
    let prompt = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Protocol("generate: missing prompt".into()))?;
    let mut req = GenerationRequest::new(prompt);
    if let Some(steps) = v.get("steps") {
        req.steps = steps
            .as_usize()
            .ok_or_else(|| Error::Protocol("steps must be a positive integer".into()))?;
    }
    if let Some(gs) = v.get("guidance_scale") {
        req.guidance_scale =
            gs.as_f64().ok_or_else(|| Error::Protocol("guidance_scale must be a number".into()))?
                as f32;
    }
    if let Some(seed) = v.get("seed") {
        let raw =
            seed.as_i64().ok_or_else(|| Error::Protocol("seed must be an integer".into()))?;
        // shared validation with TOML/CLI/workload: a negative seed is
        // a protocol error, not a silent two's-complement wrap
        req.seed = crate::config::seed_from_i64(raw).map_err(Error::Protocol)?;
    }
    if let Some(s) = v.get("scheduler") {
        req.scheduler = SchedulerKind::parse(
            s.as_str().ok_or_else(|| Error::Protocol("scheduler must be a string".into()))?,
        )?;
    }
    // ---- the schedule surface: type extraction only — mutual
    // exclusion and per-kind dispatch live in
    // GuidanceSchedule::from_parts, shared with the TOML and CLI
    // surfaces
    let position = match v.get("window_position") {
        Some(p) => Some(
            WindowPosition::parse(p.as_str().ok_or_else(|| {
                Error::Protocol("window_position must be a string".into())
            })?)
            .map_err(|e| Error::Protocol(e.to_string()))?,
        ),
        None => None,
    };
    // window_position alone still selects a (zero-width) window so a
    // typo'd combination is validated instead of silently ignored
    let window = match v.get("window_fraction") {
        Some(f) => {
            let fraction = f
                .as_f64()
                .ok_or_else(|| Error::Protocol("window_fraction must be a number".into()))?;
            Some((fraction, position.unwrap_or(WindowPosition::Last)))
        }
        None => position.map(|p| (0.0, p)),
    };
    let segments = match v.get("segments") {
        Some(s) => Some(
            s.as_str()
                .ok_or_else(|| Error::Protocol("segments must be a string".into()))?,
        ),
        None => None,
    };
    let interval = match v.get("interval") {
        Some(s) => Some(
            s.as_str()
                .ok_or_else(|| Error::Protocol("interval must be a string".into()))?,
        ),
        None => None,
    };
    let cadence = match v.get("cadence") {
        Some(s) => Some(s.as_usize().ok_or_else(|| {
            Error::Protocol("cadence must be a positive integer".into())
        })?),
        None => None,
    };
    let schedule_set =
        window.is_some() || segments.is_some() || interval.is_some() || cadence.is_some();
    if let Some(s) = GuidanceSchedule::from_parts(window, segments, interval, cadence)
        .map_err(|e| Error::Protocol(e.to_string()))?
    {
        req.schedule = s;
    }
    let strategy_set = v.get("strategy").is_some();
    if let Some(s) = v.get("strategy") {
        let name = s
            .as_str()
            .ok_or_else(|| Error::Protocol("strategy must be a string".into()))?;
        let refresh = match v.get("refresh_every") {
            Some(r) => r.as_usize().ok_or_else(|| {
                Error::Protocol("refresh_every must be a non-negative integer".into())
            })?,
            None => 0,
        };
        req.strategy = GuidanceStrategy::parse(name, refresh)?;
    } else if v.get("refresh_every").is_some() {
        return Err(Error::Protocol("refresh_every requires a strategy field".into()));
    }
    // ---- the adaptive (online) skip controller: `"adaptive": true`
    // enables it with defaults, `adaptive_*` fields refine it; knobs
    // without the switch are a protocol error (mirrors refresh_every)
    let adaptive_knobs = [
        "adaptive_threshold",
        "adaptive_patience",
        "adaptive_min_dual_fraction",
        "adaptive_probe_every",
    ];
    let adaptive_set = v.get("adaptive").is_some();
    let enabled = match v.get("adaptive") {
        Some(b) => b
            .as_bool()
            .ok_or_else(|| Error::Protocol("adaptive must be a boolean".into()))?,
        None => false,
    };
    if enabled {
        let mut a = AdaptiveConfig::default();
        if let Some(t) = v.get("adaptive_threshold") {
            a.threshold = t
                .as_f64()
                .ok_or_else(|| Error::Protocol("adaptive_threshold must be a number".into()))?;
        }
        if let Some(p) = v.get("adaptive_patience") {
            a.patience = p
                .as_usize()
                .ok_or_else(|| Error::Protocol("adaptive_patience must be an integer".into()))?;
        }
        if let Some(f) = v.get("adaptive_min_dual_fraction") {
            a.min_dual_fraction = f.as_f64().ok_or_else(|| {
                Error::Protocol("adaptive_min_dual_fraction must be a number".into())
            })?;
        }
        if let Some(p) = v.get("adaptive_probe_every") {
            a.probe_every = p.as_usize().ok_or_else(|| {
                Error::Protocol("adaptive_probe_every must be an integer".into())
            })?;
        }
        a.validate().map_err(|e| Error::Protocol(e.to_string()))?;
        req.adaptive = Some(a);
    } else if let Some(orphan) = adaptive_knobs.iter().find(|&&k| v.get(k).is_some()) {
        return Err(Error::Protocol(format!("{orphan} requires \"adaptive\": true")));
    }
    let mut meta = QosMeta::default();
    if let Some(d) = v.get("deadline_ms") {
        let ms = d
            .as_f64()
            .ok_or_else(|| Error::Protocol("deadline_ms must be a number".into()))?;
        // the upper bound keeps Duration::from_secs_f64 panic-free on
        // hostile input — a connection must never die to a bad field
        if !ms.is_finite() || ms <= 0.0 || ms > crate::qos::MAX_DEADLINE_MS {
            return Err(Error::Protocol(format!(
                "deadline_ms {ms} outside (0, {}]",
                crate::qos::MAX_DEADLINE_MS
            )));
        }
        meta.deadline = Some(std::time::Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(p) = v.get("priority") {
        meta.priority = Priority::parse(
            p.as_str().ok_or_else(|| Error::Protocol("priority must be a string".into()))?,
        )?;
    }
    let return_image = v.get("return_image").and_then(Value::as_bool).unwrap_or(false);
    let return_latent = v.get("return_latent").and_then(Value::as_bool).unwrap_or(false);
    req.decode = return_image || req.decode;
    req.validate()?;
    Ok(ServerRequest {
        request: req,
        meta,
        adaptive_set,
        schedule_set,
        strategy_set,
        return_image,
        return_latent,
    })
}

/// Render a generation failure, giving QoS outcomes their structured
/// 429/503/504-style shape so clients can branch without parsing
/// message strings.
pub fn render_failure(id: Option<i64>, e: &Error) -> Value {
    let mut v = Value::obj().with("ok", false).with("error", e.to_string());
    // qos_code() owns the error -> HTTP-code mapping; only the shape
    // flags are decided here
    if let Some(code) = e.qos_code() {
        v = v.with("code", code as i64);
    }
    match e {
        Error::Rejected { reason, .. } => {
            v = v.with("rejected", true).with("reason", reason.as_str());
        }
        Error::DeadlineExceeded(_) => {
            v = v.with("deadline_exceeded", true);
        }
        _ => {}
    }
    if let Some(id) = id {
        v = v.with("id", id);
    }
    v
}

/// Render a generation result for the wire.
pub fn render_output(id: Option<i64>, sr: &ServerRequest, out: &GenerationOutput) -> Value {
    let mut v = Value::obj()
        .with("ok", true)
        .with("wall_ms", out.wall_ms)
        .with("unet_evals", out.unet_evals as i64)
        .with("steps", out.steps as i64)
        // from the output, not sr: QoS admission may have rewritten the
        // request's strategy/schedule after parsing
        .with("strategy", out.strategy.name())
        // the executed plan summary — the same IR the eval-count
        // invariant audits, so clients can see exactly what ran
        .with("plan", out.plan_summary.as_str())
        .with("unet_cond_ms", out.breakdown.unet_cond_ms)
        .with("unet_uncond_ms", out.breakdown.unet_uncond_ms)
        .with("combine_ms", out.breakdown.combine_ms)
        .with("scheduler_ms", out.breakdown.scheduler_ms);
    if let Some(id) = id {
        v = v.with("id", id);
    }
    if sr.return_image {
        if let Some(img) = &out.image {
            if let Ok(png) = encode_png(img) {
                v = v
                    .with("png_b64", b64encode(&png))
                    .with("width", img.width as i64)
                    .with("height", img.height as i64);
            }
        }
    }
    if sr.return_latent {
        let latent: Vec<Value> = out.latent.iter().map(|&f| Value::float(f as f64)).collect();
        v = v.with("latent", Value::Arr(latent));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::WindowSpec;
    use crate::json;
    use crate::metrics::StepBreakdown;

    fn parse(s: &str) -> Result<ServerRequest> {
        parse_request(&json::from_str(s).unwrap())
    }

    #[test]
    fn full_request_parses() {
        let sr = parse(
            r#"{"op":"generate","prompt":"a cat","steps":25,"guidance_scale":9.6,
               "seed":3,"scheduler":"ddim","window_fraction":0.4,
               "window_position":"last","return_image":true}"#,
        )
        .unwrap();
        assert_eq!(sr.request.prompt, "a cat");
        assert_eq!(sr.request.steps, 25);
        assert_eq!(sr.request.guidance_scale, 9.6);
        assert_eq!(sr.request.seed, 3);
        assert_eq!(sr.request.scheduler, SchedulerKind::Ddim);
        assert_eq!(sr.request.schedule, GuidanceSchedule::Window(WindowSpec::last(0.4)));
        assert!(sr.return_image);
        assert!(!sr.return_latent);
    }

    #[test]
    fn defaults_applied() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.steps, 50);
        assert_eq!(sr.request.guidance_scale, 7.5);
        assert_eq!(sr.request.schedule, GuidanceSchedule::none());
        assert_eq!(sr.request.adaptive, None);
    }

    #[test]
    fn schedule_fields_parse() {
        let sr = parse(r#"{"op":"generate","prompt":"x","interval":"0.25-0.75"}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 });
        let sr = parse(r#"{"op":"generate","prompt":"x","cadence":4}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Cadence { every: 4 });
        let sr =
            parse(r#"{"op":"generate","prompt":"x","segments":"0.0-0.2,0.8-1.0"}"#).unwrap();
        assert!(matches!(sr.request.schedule, GuidanceSchedule::Segments(ref s) if s.len() == 2));
        // offset placements round-trip through the shared parser
        let sr = parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.25,
               "window_position":"offset(0.5)"}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.schedule,
            GuidanceSchedule::Window(WindowSpec::at_offset(0.5, 0.25))
        );
        // schedule_set records whether any schedule field was explicit
        assert!(sr.schedule_set);
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":4}"#).unwrap().schedule_set);
        assert!(!parse(r#"{"op":"generate","prompt":"x"}"#).unwrap().schedule_set);
        // schedule fields are mutually exclusive
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":4,"interval":"0.2-0.8"}"#)
            .is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.2,"cadence":4}"#
        )
        .is_err());
        // invalid values are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","cadence":0}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","interval":"0.8-0.2"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","segments":7}"#).is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.2,
               "window_position":"offset(2.0)"}"#
        )
        .is_err());
        // window_position alone is validated, not silently dropped
        assert!(parse(r#"{"op":"generate","prompt":"x","window_position":"bogus"}"#).is_err());
        let sr = parse(r#"{"op":"generate","prompt":"x","window_position":"first"}"#).unwrap();
        assert_eq!(sr.request.schedule, GuidanceSchedule::Window(WindowSpec::first(0.0)));
        assert!(sr.schedule_set);
    }

    #[test]
    fn adaptive_fields_parse() {
        let sr = parse(r#"{"op":"generate","prompt":"x","adaptive":true}"#).unwrap();
        assert_eq!(sr.request.adaptive, Some(AdaptiveConfig::default()));
        let sr = parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_threshold":0.1,
               "adaptive_patience":3,"adaptive_min_dual_fraction":0.4,
               "adaptive_probe_every":6}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.adaptive,
            Some(AdaptiveConfig {
                threshold: 0.1,
                patience: 3,
                min_dual_fraction: 0.4,
                probe_every: 6
            })
        );
        // explicit off — adaptive_set records the client's explicit
        // choice so a server-side adaptive default cannot override it
        let sr = parse(r#"{"op":"generate","prompt":"x","adaptive":false}"#).unwrap();
        assert_eq!(sr.request.adaptive, None);
        assert!(sr.adaptive_set);
        assert!(!parse(r#"{"op":"generate","prompt":"x"}"#).unwrap().adaptive_set);
        // adaptive + an explicit schedule is a conflict, not a silent
        // precedence rule (the engine would ignore the schedule)
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive":true,"cadence":4}"#).is_err());
        // orphan knobs and bad values are protocol errors
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive_threshold":0.1}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","adaptive":7}"#).is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_threshold":-1}"#
        )
        .is_err());
        assert!(parse(
            r#"{"op":"generate","prompt":"x","adaptive":true,"adaptive_min_dual_fraction":2.0}"#
        )
        .is_err());
    }

    #[test]
    fn seed_round_trips_and_negatives_rejected() {
        // valid seeds round-trip exactly, including large ones
        let sr = parse(r#"{"op":"generate","prompt":"x","seed":0}"#).unwrap();
        assert_eq!(sr.request.seed, 0);
        let sr =
            parse(r#"{"op":"generate","prompt":"x","seed":9007199254740991}"#).unwrap();
        assert_eq!(sr.request.seed, 9007199254740991);
        // a negative seed used to wrap through `as u64` into a
        // valid-looking 18-quintillion seed; now it's a typed rejection
        let err = parse(r#"{"op":"generate","prompt":"x","seed":-1}"#).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err:?}");
        assert!(err.to_string().contains("seed must be >= 0"));
        assert!(parse(r#"{"op":"generate","prompt":"x","seed":"lucky"}"#).is_err());
    }

    #[test]
    fn invalid_requests_rejected() {
        assert!(parse(r#"{"op":"generate"}"#).is_err()); // no prompt
        assert!(parse(r#"{"op":"generate","prompt":"x","steps":-1}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","window_fraction":3.0}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","scheduler":"bogus"}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","window_fraction":0.2,"window_position":"bogus"}"#)
                .is_err()
        );
    }

    #[test]
    fn strategy_fields_parse() {
        use crate::guidance::ReuseKind;
        let sr = parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.3,
               "strategy":"hold","refresh_every":4}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        let sr = parse(r#"{"op":"generate","prompt":"x","strategy":"extrapolate"}"#).unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 }
        );
        // default stays the paper's drop-guidance mode
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.strategy, GuidanceStrategy::CondOnly);
        // bad fields are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":"warp"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":7}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","strategy":"hold","refresh_every":-1}"#)
                .is_err()
        );
        assert!(parse(r#"{"op":"generate","prompt":"x","refresh_every":2}"#).is_err());
    }

    #[test]
    fn qos_fields_parse() {
        let sr = parse(
            r#"{"op":"generate","prompt":"x","deadline_ms":250.5,"priority":"interactive"}"#,
        )
        .unwrap();
        assert!((sr.meta.deadline_ms().unwrap() - 250.5).abs() < 1e-9);
        assert_eq!(sr.meta.priority, crate::qos::Priority::Interactive);
        // defaults: no deadline, standard priority
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.meta, crate::qos::QosMeta::default());
    }

    #[test]
    fn bad_qos_fields_rejected() {
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":-5}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":"soon"}"#).is_err());
        // overflow guard: a huge deadline is a protocol error, not a
        // Duration::from_secs_f64 panic killing the connection
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":1e30}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":"urgent"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":3}"#).is_err());
    }

    #[test]
    fn rejection_renders_structured() {
        let e = Error::Rejected {
            code: 429,
            reason: "queue full: depth 8 >= class limit 8".into(),
        };
        let v = render_failure(Some(4), &e);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("rejected").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("code").unwrap().as_i64(), Some(429));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(4));
        assert!(v.get("reason").unwrap().as_str().unwrap().contains("queue full"));

        let d = render_failure(None, &Error::DeadlineExceeded("expired in queue".into()));
        assert_eq!(d.get("deadline_exceeded").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("code").unwrap().as_i64(), Some(504));

        // ordinary errors keep the legacy shape
        let o = render_failure(None, &Error::Request("empty prompt".into()));
        assert!(o.get("code").is_none());
        assert!(o.get("error").unwrap().as_str().unwrap().contains("empty prompt"));
    }

    #[test]
    fn render_includes_metrics() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        let out = GenerationOutput {
            latent: vec![0.5, -0.5],
            image: None,
            wall_ms: 123.4,
            breakdown: StepBreakdown { unet_cond_ms: 100.0, ..Default::default() },
            unet_evals: 90,
            steps: 50,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "40D 10C".into(),
        };
        let v = render_output(Some(7), &sr, &out);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(7));
        assert_eq!(v.get("unet_evals").unwrap().as_i64(), Some(90));
        // the echoed strategy comes from the executed output, not the
        // parsed request (QoS admission may rewrite it)
        assert_eq!(v.get("strategy").unwrap().as_str(), Some("cond-only"));
        // the executed plan is echoed from the same IR the invariant audits
        assert_eq!(v.get("plan").unwrap().as_str(), Some("40D 10C"));
        assert!(v.get("png_b64").is_none());
        assert!(v.get("latent").is_none());
    }

    #[test]
    fn render_latent_when_requested() {
        let mut sr = parse(r#"{"op":"generate","prompt":"x","return_latent":true}"#).unwrap();
        sr.return_latent = true;
        let out = GenerationOutput {
            latent: vec![1.0, 2.0],
            image: None,
            wall_ms: 1.0,
            breakdown: StepBreakdown::default(),
            unet_evals: 2,
            steps: 1,
            strategy: GuidanceStrategy::CondOnly,
            plan_summary: "1D".into(),
        };
        let v = render_output(None, &sr, &out);
        let arr = v.get("latent").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_f64(), Some(1.0));
    }
}
