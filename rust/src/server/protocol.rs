//! Wire-format mapping: JSON objects <-> engine request/output types.

use crate::engine::{GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};
use crate::guidance::{GuidanceStrategy, WindowSpec};
use crate::image::encode_png;
use crate::json::Value;
use crate::qos::{Priority, QosMeta};
use crate::scheduler::SchedulerKind;

use super::base64::b64encode;

/// A parsed `generate` operation.
#[derive(Debug, Clone)]
pub struct ServerRequest {
    pub request: GenerationRequest,
    /// Serving metadata: deadline + priority class (QoS admission).
    pub meta: QosMeta,
    /// Include the PNG (base64) in the response.
    pub return_image: bool,
    /// Include the raw final latent in the response.
    pub return_latent: bool,
}

/// Parse a `{"op":"generate", ...}` JSON object.
pub fn parse_request(v: &Value) -> Result<ServerRequest> {
    let prompt = v
        .get("prompt")
        .and_then(Value::as_str)
        .ok_or_else(|| Error::Protocol("generate: missing prompt".into()))?;
    let mut req = GenerationRequest::new(prompt);
    if let Some(steps) = v.get("steps") {
        req.steps = steps
            .as_usize()
            .ok_or_else(|| Error::Protocol("steps must be a positive integer".into()))?;
    }
    if let Some(gs) = v.get("guidance_scale") {
        req.guidance_scale =
            gs.as_f64().ok_or_else(|| Error::Protocol("guidance_scale must be a number".into()))?
                as f32;
    }
    if let Some(seed) = v.get("seed") {
        req.seed =
            seed.as_i64().ok_or_else(|| Error::Protocol("seed must be an integer".into()))? as u64;
    }
    if let Some(s) = v.get("scheduler") {
        req.scheduler = SchedulerKind::parse(
            s.as_str().ok_or_else(|| Error::Protocol("scheduler must be a string".into()))?,
        )?;
    }
    if let Some(f) = v.get("window_fraction") {
        let fraction = f
            .as_f64()
            .ok_or_else(|| Error::Protocol("window_fraction must be a number".into()))?;
        let position = v
            .get("window_position")
            .map(|p| {
                p.as_str()
                    .map(String::from)
                    .ok_or_else(|| Error::Protocol("window_position must be a string".into()))
            })
            .transpose()?
            .unwrap_or_else(|| "last".into());
        req.window = match position.as_str() {
            "last" => WindowSpec::last(fraction),
            "first" => WindowSpec::first(fraction),
            "middle" => WindowSpec::middle(fraction),
            other => {
                return Err(Error::Protocol(format!("unknown window_position {other:?}")))
            }
        };
    }
    if let Some(s) = v.get("strategy") {
        let name = s
            .as_str()
            .ok_or_else(|| Error::Protocol("strategy must be a string".into()))?;
        let refresh = match v.get("refresh_every") {
            Some(r) => r.as_usize().ok_or_else(|| {
                Error::Protocol("refresh_every must be a non-negative integer".into())
            })?,
            None => 0,
        };
        req.strategy = GuidanceStrategy::parse(name, refresh)?;
    } else if v.get("refresh_every").is_some() {
        return Err(Error::Protocol("refresh_every requires a strategy field".into()));
    }
    let mut meta = QosMeta::default();
    if let Some(d) = v.get("deadline_ms") {
        let ms = d
            .as_f64()
            .ok_or_else(|| Error::Protocol("deadline_ms must be a number".into()))?;
        // the upper bound keeps Duration::from_secs_f64 panic-free on
        // hostile input — a connection must never die to a bad field
        if !ms.is_finite() || ms <= 0.0 || ms > crate::qos::MAX_DEADLINE_MS {
            return Err(Error::Protocol(format!(
                "deadline_ms {ms} outside (0, {}]",
                crate::qos::MAX_DEADLINE_MS
            )));
        }
        meta.deadline = Some(std::time::Duration::from_secs_f64(ms / 1e3));
    }
    if let Some(p) = v.get("priority") {
        meta.priority = Priority::parse(
            p.as_str().ok_or_else(|| Error::Protocol("priority must be a string".into()))?,
        )?;
    }
    let return_image = v.get("return_image").and_then(Value::as_bool).unwrap_or(false);
    let return_latent = v.get("return_latent").and_then(Value::as_bool).unwrap_or(false);
    req.decode = return_image || req.decode;
    req.validate()?;
    Ok(ServerRequest { request: req, meta, return_image, return_latent })
}

/// Render a generation failure, giving QoS outcomes their structured
/// 429/503/504-style shape so clients can branch without parsing
/// message strings.
pub fn render_failure(id: Option<i64>, e: &Error) -> Value {
    let mut v = Value::obj().with("ok", false).with("error", e.to_string());
    // qos_code() owns the error -> HTTP-code mapping; only the shape
    // flags are decided here
    if let Some(code) = e.qos_code() {
        v = v.with("code", code as i64);
    }
    match e {
        Error::Rejected { reason, .. } => {
            v = v.with("rejected", true).with("reason", reason.as_str());
        }
        Error::DeadlineExceeded(_) => {
            v = v.with("deadline_exceeded", true);
        }
        _ => {}
    }
    if let Some(id) = id {
        v = v.with("id", id);
    }
    v
}

/// Render a generation result for the wire.
pub fn render_output(id: Option<i64>, sr: &ServerRequest, out: &GenerationOutput) -> Value {
    let mut v = Value::obj()
        .with("ok", true)
        .with("wall_ms", out.wall_ms)
        .with("unet_evals", out.unet_evals as i64)
        .with("steps", out.steps as i64)
        // from the output, not sr: QoS admission may have rewritten the
        // request's strategy/window after parsing
        .with("strategy", out.strategy.name())
        .with("unet_cond_ms", out.breakdown.unet_cond_ms)
        .with("unet_uncond_ms", out.breakdown.unet_uncond_ms)
        .with("combine_ms", out.breakdown.combine_ms)
        .with("scheduler_ms", out.breakdown.scheduler_ms);
    if let Some(id) = id {
        v = v.with("id", id);
    }
    if sr.return_image {
        if let Some(img) = &out.image {
            if let Ok(png) = encode_png(img) {
                v = v
                    .with("png_b64", b64encode(&png))
                    .with("width", img.width as i64)
                    .with("height", img.height as i64);
            }
        }
    }
    if sr.return_latent {
        let latent: Vec<Value> = out.latent.iter().map(|&f| Value::float(f as f64)).collect();
        v = v.with("latent", Value::Arr(latent));
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;
    use crate::metrics::StepBreakdown;

    fn parse(s: &str) -> Result<ServerRequest> {
        parse_request(&json::from_str(s).unwrap())
    }

    #[test]
    fn full_request_parses() {
        let sr = parse(
            r#"{"op":"generate","prompt":"a cat","steps":25,"guidance_scale":9.6,
               "seed":3,"scheduler":"ddim","window_fraction":0.4,
               "window_position":"last","return_image":true}"#,
        )
        .unwrap();
        assert_eq!(sr.request.prompt, "a cat");
        assert_eq!(sr.request.steps, 25);
        assert_eq!(sr.request.guidance_scale, 9.6);
        assert_eq!(sr.request.seed, 3);
        assert_eq!(sr.request.scheduler, SchedulerKind::Ddim);
        assert_eq!(sr.request.window, WindowSpec::last(0.4));
        assert!(sr.return_image);
        assert!(!sr.return_latent);
    }

    #[test]
    fn defaults_applied() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.steps, 50);
        assert_eq!(sr.request.guidance_scale, 7.5);
        assert_eq!(sr.request.window, WindowSpec::none());
    }

    #[test]
    fn invalid_requests_rejected() {
        assert!(parse(r#"{"op":"generate"}"#).is_err()); // no prompt
        assert!(parse(r#"{"op":"generate","prompt":"x","steps":-1}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","window_fraction":3.0}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","scheduler":"bogus"}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","window_fraction":0.2,"window_position":"bogus"}"#)
                .is_err()
        );
    }

    #[test]
    fn strategy_fields_parse() {
        use crate::guidance::ReuseKind;
        let sr = parse(
            r#"{"op":"generate","prompt":"x","window_fraction":0.3,
               "strategy":"hold","refresh_every":4}"#,
        )
        .unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        let sr = parse(r#"{"op":"generate","prompt":"x","strategy":"extrapolate"}"#).unwrap();
        assert_eq!(
            sr.request.strategy,
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 }
        );
        // default stays the paper's drop-guidance mode
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.request.strategy, GuidanceStrategy::CondOnly);
        // bad fields are protocol errors, not silent defaults
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":"warp"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","strategy":7}"#).is_err());
        assert!(
            parse(r#"{"op":"generate","prompt":"x","strategy":"hold","refresh_every":-1}"#)
                .is_err()
        );
        assert!(parse(r#"{"op":"generate","prompt":"x","refresh_every":2}"#).is_err());
    }

    #[test]
    fn qos_fields_parse() {
        let sr = parse(
            r#"{"op":"generate","prompt":"x","deadline_ms":250.5,"priority":"interactive"}"#,
        )
        .unwrap();
        assert!((sr.meta.deadline_ms().unwrap() - 250.5).abs() < 1e-9);
        assert_eq!(sr.meta.priority, crate::qos::Priority::Interactive);
        // defaults: no deadline, standard priority
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        assert_eq!(sr.meta, crate::qos::QosMeta::default());
    }

    #[test]
    fn bad_qos_fields_rejected() {
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":-5}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":"soon"}"#).is_err());
        // overflow guard: a huge deadline is a protocol error, not a
        // Duration::from_secs_f64 panic killing the connection
        assert!(parse(r#"{"op":"generate","prompt":"x","deadline_ms":1e30}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":"urgent"}"#).is_err());
        assert!(parse(r#"{"op":"generate","prompt":"x","priority":3}"#).is_err());
    }

    #[test]
    fn rejection_renders_structured() {
        let e = Error::Rejected {
            code: 429,
            reason: "queue full: depth 8 >= class limit 8".into(),
        };
        let v = render_failure(Some(4), &e);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("rejected").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("code").unwrap().as_i64(), Some(429));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(4));
        assert!(v.get("reason").unwrap().as_str().unwrap().contains("queue full"));

        let d = render_failure(None, &Error::DeadlineExceeded("expired in queue".into()));
        assert_eq!(d.get("deadline_exceeded").unwrap().as_bool(), Some(true));
        assert_eq!(d.get("code").unwrap().as_i64(), Some(504));

        // ordinary errors keep the legacy shape
        let o = render_failure(None, &Error::Request("empty prompt".into()));
        assert!(o.get("code").is_none());
        assert!(o.get("error").unwrap().as_str().unwrap().contains("empty prompt"));
    }

    #[test]
    fn render_includes_metrics() {
        let sr = parse(r#"{"op":"generate","prompt":"x"}"#).unwrap();
        let out = GenerationOutput {
            latent: vec![0.5, -0.5],
            image: None,
            wall_ms: 123.4,
            breakdown: StepBreakdown { unet_cond_ms: 100.0, ..Default::default() },
            unet_evals: 90,
            steps: 50,
            strategy: GuidanceStrategy::CondOnly,
        };
        let v = render_output(Some(7), &sr, &out);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(7));
        assert_eq!(v.get("unet_evals").unwrap().as_i64(), Some(90));
        // the echoed strategy comes from the executed output, not the
        // parsed request (QoS admission may rewrite it)
        assert_eq!(v.get("strategy").unwrap().as_str(), Some("cond-only"));
        assert!(v.get("png_b64").is_none());
        assert!(v.get("latent").is_none());
    }

    #[test]
    fn render_latent_when_requested() {
        let mut sr = parse(r#"{"op":"generate","prompt":"x","return_latent":true}"#).unwrap();
        sr.return_latent = true;
        let out = GenerationOutput {
            latent: vec![1.0, 2.0],
            image: None,
            wall_ms: 1.0,
            breakdown: StepBreakdown::default(),
            unet_evals: 2,
            steps: 1,
            strategy: GuidanceStrategy::CondOnly,
        };
        let v = render_output(None, &sr, &out);
        let arr = v.get("latent").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].as_f64(), Some(1.0));
    }
}
