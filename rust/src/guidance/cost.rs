//! Analytic cost model for selective guidance (§3.3 of the paper).
//!
//! "The speed-up observed was approximately half of the number of
//! iterations that had been optimized. This is because the denoising
//! UNet comprises the bulk of the computation." With UNet share `u` of
//! the per-image time and optimized fraction `f`:
//!
//! ```text
//! saving(f) = f * u / 2
//! ```
//!
//! (each optimized iteration drops one of its two UNet passes). The
//! benches validate measured savings against this model; EXPERIMENTS.md
//! reports both.

use super::plan::GuidancePlan;
use super::policy::SelectiveGuidancePolicy;

/// Per-component cost estimates for one image generation.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Mean time of ONE UNet evaluation (seconds).
    pub unet_eval_s: f64,
    /// Per-iteration non-UNet overhead: combine + scheduler + transfers.
    pub per_step_overhead_s: f64,
    /// One-off costs: text encoding, latent init, VAE decode, PNG.
    pub fixed_s: f64,
}

impl CostModel {
    /// Predicted end-to-end seconds for a compiled [`GuidancePlan`] —
    /// the plan-IR view every other prediction routes through.
    pub fn predict_plan(&self, plan: &GuidancePlan) -> f64 {
        plan.total_unet_evals() as f64 * self.unet_eval_s
            + plan.len() as f64 * self.per_step_overhead_s
            + self.fixed_s
    }

    /// Predicted end-to-end seconds for an `n`-step trajectory.
    pub fn predict(&self, policy: &SelectiveGuidancePolicy, n: usize) -> f64 {
        self.predict_plan(&policy.plan(n))
    }

    /// Predicted fractional saving vs the dual-pass baseline.
    pub fn predicted_saving(&self, policy: &SelectiveGuidancePolicy, n: usize) -> f64 {
        let base = self.predict(&SelectiveGuidancePolicy::baseline(), n);
        let opt = self.predict(policy, n);
        (base - opt) / base
    }

    /// The paper's idealized model (UNet is 100% of the time):
    /// saving = f / 2.
    pub fn ideal_saving(fraction: f64) -> f64 {
        fraction / 2.0
    }

    /// Idealized saving for a reuse strategy: refresh steps pay the dual
    /// cost back, so only the strategy's *effective* single-pass fraction
    /// saves (see [`super::GuidanceStrategy::effective_fraction`]).
    pub fn ideal_saving_for(strategy: &super::GuidanceStrategy, fraction: f64) -> f64 {
        strategy.effective_fraction(fraction) / 2.0
    }

    /// UNet share of baseline time under this model.
    pub fn unet_share(&self, n: usize) -> f64 {
        let unet = 2.0 * n as f64 * self.unet_eval_s;
        unet / (unet + n as f64 * self.per_step_overhead_s + self.fixed_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::WindowSpec;
    use crate::testutil::prop::forall;

    fn policy(f: f64) -> SelectiveGuidancePolicy {
        SelectiveGuidancePolicy::new(WindowSpec::last(f), 7.5).unwrap()
    }

    #[test]
    fn pure_unet_model_matches_paper_formula() {
        // zero overheads: saving must be exactly k/(2n)
        let m = CostModel { unet_eval_s: 0.1, per_step_overhead_s: 0.0, fixed_s: 0.0 };
        for (f, expect) in [(0.2, 0.1), (0.3, 0.15), (0.4, 0.2), (0.5, 0.25)] {
            let s = m.predicted_saving(&policy(f), 50);
            assert!((s - expect).abs() < 1e-12, "f={f}: {s} vs {expect}");
        }
    }

    #[test]
    fn paper_table1_savings_with_overhead() {
        // Table 1 measured savings (8.2/12.1/16.2/20.3%) are slightly
        // below the ideal f/2 — consistent with a UNet share < 100%.
        // With ~81% UNet share the model reproduces the paper's numbers.
        let m = CostModel { unet_eval_s: 0.0805, per_step_overhead_s: 0.012, fixed_s: 1.26 };
        let expected = [(0.2, 0.082), (0.3, 0.121), (0.4, 0.162), (0.5, 0.203)];
        for (f, paper) in expected {
            let s = m.predicted_saving(&policy(f), 50);
            assert!(
                (s - paper).abs() < 0.015,
                "f={f}: model {s:.3} vs paper {paper:.3}"
            );
        }
    }

    #[test]
    fn ideal_saving_formula() {
        assert_eq!(CostModel::ideal_saving(0.2), 0.1);
        assert_eq!(CostModel::ideal_saving(0.5), 0.25);
    }

    #[test]
    fn saving_monotone_in_fraction() {
        forall("cost monotone", 100, |g| {
            let m = CostModel {
                unet_eval_s: g.f64_in(0.001, 1.0),
                per_step_overhead_s: g.f64_in(0.0, 0.1),
                fixed_s: g.f64_in(0.0, 2.0),
            };
            let n = g.usize_in(10, 200);
            let f1 = g.f64_in(0.0, 0.5);
            let f2 = g.f64_in(f1, 1.0);
            let s1 = m.predicted_saving(&policy(f1), n);
            let s2 = m.predicted_saving(&policy(f2), n);
            assert!(s2 >= s1 - 1e-12, "saving not monotone: {s1} -> {s2}");
            // bounded by the ideal model
            assert!(s2 <= CostModel::ideal_saving(1.0) + 1e-12);
        });
    }

    #[test]
    fn reuse_saving_sits_between_dual_and_cond_only() {
        use crate::guidance::{GuidanceStrategy, ReuseKind};
        // pure-UNet model: cond-only saves f/2, reuse with refresh m
        // saves f/2 · m/(m+1), dual saves nothing
        let m = CostModel { unet_eval_s: 0.1, per_step_overhead_s: 0.0, fixed_s: 0.0 };
        let n = 50;
        let w = WindowSpec::last(0.4);
        let cond = SelectiveGuidancePolicy::new(w, 7.5).unwrap();
        let hold = SelectiveGuidancePolicy::with_strategy(
            w,
            7.5,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 },
        )
        .unwrap();
        let s_cond = m.predicted_saving(&cond, n);
        let s_hold = m.predicted_saving(&hold, n);
        assert!(s_hold > 0.0, "reuse must still save: {s_hold}");
        assert!(s_hold < s_cond, "refresh steps must cost: {s_hold} vs {s_cond}");
        // the ideal model brackets it (cold-start makes the real count
        // differ by at most one refresh step)
        let ideal = CostModel::ideal_saving_for(&hold.strategy(), 0.4);
        assert!((s_hold - ideal).abs() < 0.02, "model {s_hold} vs ideal {ideal}");
    }

    #[test]
    fn predict_routes_through_the_plan() {
        let m = CostModel { unet_eval_s: 0.1, per_step_overhead_s: 0.01, fixed_s: 0.5 };
        let p = policy(0.4);
        assert_eq!(m.predict(&p, 50), m.predict_plan(&p.plan(50)));
        // a richer schedule prices through the same IR
        let q = SelectiveGuidancePolicy::with_schedule(
            crate::guidance::GuidanceSchedule::Cadence { every: 2 },
            7.5,
            crate::guidance::GuidanceStrategy::CondOnly,
        )
        .unwrap();
        // 50 steps, dual every 2nd: 25 dual + 25 single = 75 evals
        assert_eq!(q.plan(50).total_unet_evals(), 75);
        assert!((m.predict_plan(&q.plan(50)) - (75.0 * 0.1 + 50.0 * 0.01 + 0.5)).abs() < 1e-12);
    }

    #[test]
    fn unet_share_bounds() {
        let m = CostModel { unet_eval_s: 0.1, per_step_overhead_s: 0.01, fixed_s: 0.5 };
        let share = m.unet_share(50);
        assert!(share > 0.0 && share < 1.0);
        let m2 = CostModel { unet_eval_s: 0.1, per_step_overhead_s: 0.0, fixed_s: 0.0 };
        assert_eq!(m2.unet_share(50), 1.0);
    }
}
