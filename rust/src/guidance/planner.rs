//! Deadline-optimal plan search: the offline Pareto tuner and its O(1)
//! admission-time consumer (DESIGN.md §16).
//!
//! The QoS [`crate::qos::WindowActuator`] reacts to load by *widening* a
//! request's existing `Last` window — one ray through the schedule
//! grammar. But the grammar (segments × interval × cadence × reuse) holds
//! points that buy the same milliseconds back at strictly higher SSIM
//! (the `fig6_interval_guidance` result: cadence/interval reuse beats a
//! cond-only tail window at equal eval budget). The planner closes that
//! gap in two phases:
//!
//! * **Offline** — [`tune_frontier`] sweeps [`TunerConfig::candidates`]
//!   on the deterministic stack, scores each candidate with SSIM-vs-full-
//!   CFG (a caller-supplied closure, so this module stays engine-free)
//!   and prices it with [`GuidancePlan::cost_ms`] under the attached
//!   [`CostTable`], then keeps only the non-dominated set per steps
//!   bucket. **Dominance rule:** point A dominates B when
//!   `A.cost_ms <= B.cost_ms` and `A.ssim >= B.ssim` with at least one
//!   strict; the surviving frontier is strictly increasing in *both*
//!   cost and SSIM. The result travels as a sealed [`FrontierManifest`]
//!   — same version-gate / FNV-1a checksum / fingerprint-binding
//!   machinery as [`super::CostManifest`], so a tampered frontier is
//!   refused with a typed [`Error::Artifact`].
//! * **Online** — [`PlanSearch::select`] answers "max quality that fits
//!   this saving budget" with a bucket lookup plus one binary search
//!   over the sorted frontier: O(log points), never a grammar sweep.
//!   The searches / frontier-hit / fallback / floor-clamp counters are
//!   the ledger the `plan_search` bench audits O(1) admission against
//!   (candidate evaluation happens **only** at tune time —
//!   [`FrontierManifest::candidates_swept`] is sealed and constant).
//!
//! Every frontier point is still an ordinary `(schedule, strategy)` pair
//! compiled through [`GuidancePlan::compile`], so the eval-count
//! invariant and bit-exactness suites cover searched plans unchanged:
//! the planner is a pure pre-admission transform.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use super::cost_table::fnv1a_hex;
use super::plan::{GuidancePlan, GuidanceSchedule};
use super::strategy::{GuidanceStrategy, ReuseKind};
use super::window::{WindowPosition, WindowSpec};
use super::CostTable;
use crate::error::{Error, Result};
use crate::json::{self, Value};

/// Frontier-manifest format version (bump on any shape change).
pub const FRONTIER_MANIFEST_VERSION: i64 = 1;

/// One non-dominated `(schedule, strategy)` point of a frontier bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierPoint {
    /// Human label for tables and trace events, e.g. `"cadence /4 × hold/4"`.
    pub label: String,
    pub schedule: GuidanceSchedule,
    pub strategy: GuidanceStrategy,
    /// SSIM against the full-CFG baseline at this bucket's step count
    /// (1.0 = bit-identical to the baseline).
    pub ssim: f64,
    /// Priced plan cost under the tune-time [`CostTable`].
    pub cost_ms: f64,
}

impl FrontierPoint {
    /// Fraction of the bucket's full-CFG cost this point saves.
    pub fn saving(&self, full_cost_ms: f64) -> f64 {
        if full_cost_ms <= 0.0 {
            return 0.0;
        }
        (1.0 - self.cost_ms / full_cost_ms).clamp(0.0, 1.0)
    }
}

/// The serialized schedule shape: a `(kind, spec)` string pair that
/// round-trips every [`GuidanceSchedule`] variant through the same
/// parsers the TOML/CLI/wire surfaces use.
fn schedule_to_spec(s: &GuidanceSchedule) -> (&'static str, String) {
    match s {
        GuidanceSchedule::Window(w) => ("window", format!("{}@{}", w.fraction, w.position.name())),
        GuidanceSchedule::Segments(segs) => {
            let items: Vec<String> = segs
                .iter()
                .map(|seg| {
                    let bang =
                        if seg.mode == super::plan::SegmentMode::Dual { "!" } else { "" };
                    format!("{bang}{}-{}", seg.lo, seg.hi)
                })
                .collect();
            ("segments", items.join(","))
        }
        GuidanceSchedule::Interval { lo, hi } => ("interval", format!("{lo}-{hi}")),
        GuidanceSchedule::Cadence { every } => ("cadence", format!("{every}")),
    }
}

fn schedule_from_spec(kind: &str, spec: &str) -> Result<GuidanceSchedule> {
    let sched = match kind {
        "window" => {
            let (fraction, position) = spec.split_once('@').ok_or_else(|| {
                Error::Artifact(format!("frontier window spec {spec:?} must be \"fraction@position\""))
            })?;
            let fraction: f64 = fraction.parse().map_err(|_| {
                Error::Artifact(format!("frontier window spec {spec:?}: bad fraction"))
            })?;
            GuidanceSchedule::Window(WindowSpec { fraction, position: WindowPosition::parse(position)? })
        }
        "segments" => GuidanceSchedule::parse_segments(spec)?,
        "interval" => GuidanceSchedule::parse_interval(spec)?,
        "cadence" => GuidanceSchedule::Cadence {
            every: spec.parse().map_err(|_| {
                Error::Artifact(format!("frontier cadence spec {spec:?} is not an integer"))
            })?,
        },
        other => {
            return Err(Error::Artifact(format!("frontier schedule kind {other:?} unknown")))
        }
    };
    sched.validate()?;
    Ok(sched)
}

fn strategy_refresh(s: GuidanceStrategy) -> usize {
    match s {
        GuidanceStrategy::CondOnly => 0,
        GuidanceStrategy::Reuse { refresh_every, .. } => refresh_every,
    }
}

/// The frontier of one steps bucket: points sorted by ascending
/// `cost_ms` (descending saving) and strictly ascending `ssim`.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierBucket {
    /// Step count the points were tuned at.
    pub steps: usize,
    /// Priced cost of the full-CFG baseline at this step count — the
    /// denominator of every saving computation.
    pub full_cost_ms: f64,
    pub points: Vec<FrontierPoint>,
}

impl FrontierBucket {
    /// A bucket the search can trust: at least one point, finite prices,
    /// and strict non-domination (cost and SSIM both strictly increase).
    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            return Err(Error::Artifact("frontier bucket steps must be >= 1".into()));
        }
        if !self.full_cost_ms.is_finite() || self.full_cost_ms <= 0.0 {
            return Err(Error::Artifact(format!(
                "frontier bucket {}: full_cost_ms {} must be finite and > 0",
                self.steps, self.full_cost_ms
            )));
        }
        if self.points.is_empty() {
            return Err(Error::Artifact(format!("frontier bucket {} has no points", self.steps)));
        }
        for w in self.points.windows(2) {
            if !(w[1].cost_ms > w[0].cost_ms && w[1].ssim > w[0].ssim) {
                return Err(Error::Artifact(format!(
                    "frontier bucket {}: points {:?} and {:?} are not strictly non-dominated",
                    self.steps, w[0].label, w[1].label
                )));
            }
        }
        Ok(())
    }
}

/// The sealed tuning artifact: the per-bucket frontiers plus the
/// provenance (tool version, backend, model fingerprint, sweep size) a
/// replica validates before trusting it. Same seal discipline as
/// [`super::CostManifest`]: FNV-1a over the canonical JSON minus the
/// `checksum` field, version-gated before anything else.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontierManifest {
    pub version: i64,
    /// Crate version of the tuner that produced the frontier.
    pub tool_version: String,
    pub backend: String,
    pub preset: String,
    /// FNV-1a fingerprint of the model shape (16 hex digits).
    pub model_fingerprint: String,
    /// Latent resolution the SSIM scores bind to.
    pub resolution: usize,
    /// Guidance scale the candidates were compiled and scored at.
    pub guidance_scale: f32,
    /// Grammar candidates evaluated per bucket at tune time — the
    /// constant side of the O(1)-admission ledger.
    pub candidates_swept: usize,
    /// Buckets sorted by ascending step count.
    pub buckets: Vec<FrontierBucket>,
    /// FNV-1a (16 hex digits) over the canonical JSON minus this field.
    pub checksum: String,
}

impl FrontierManifest {
    /// Build and seal a manifest (computes the checksum).
    #[allow(clippy::too_many_arguments)]
    pub fn seal(
        tool_version: impl Into<String>,
        backend: impl Into<String>,
        preset: impl Into<String>,
        model_fingerprint: impl Into<String>,
        resolution: usize,
        guidance_scale: f32,
        candidates_swept: usize,
        buckets: Vec<FrontierBucket>,
    ) -> FrontierManifest {
        let mut m = FrontierManifest {
            version: FRONTIER_MANIFEST_VERSION,
            tool_version: tool_version.into(),
            backend: backend.into(),
            preset: preset.into(),
            model_fingerprint: model_fingerprint.into(),
            resolution,
            guidance_scale,
            candidates_swept,
            buckets,
            checksum: String::new(),
        };
        m.checksum = m.compute_checksum();
        m
    }

    /// The canonical payload — everything but the seal.
    fn payload_json(&self) -> Value {
        Value::obj()
            .with("frontier_manifest_version", self.version)
            .with("tool_version", self.tool_version.as_str())
            .with("backend", self.backend.as_str())
            .with("preset", self.preset.as_str())
            .with("model_fingerprint", self.model_fingerprint.as_str())
            .with("resolution", self.resolution)
            .with("guidance_scale", self.guidance_scale as f64)
            .with("candidates_swept", self.candidates_swept)
            .with(
                "buckets",
                Value::Arr(
                    self.buckets
                        .iter()
                        .map(|b| {
                            Value::obj()
                                .with("steps", b.steps)
                                .with("full_cost_ms", b.full_cost_ms)
                                .with(
                                    "points",
                                    Value::Arr(
                                        b.points
                                            .iter()
                                            .map(|p| {
                                                let (kind, spec) = schedule_to_spec(&p.schedule);
                                                Value::obj()
                                                    .with("label", p.label.as_str())
                                                    .with("schedule_kind", kind)
                                                    .with("schedule_spec", spec)
                                                    .with("strategy", p.strategy.name())
                                                    .with(
                                                        "refresh_every",
                                                        strategy_refresh(p.strategy),
                                                    )
                                                    .with("ssim", p.ssim)
                                                    .with("cost_ms", p.cost_ms)
                                            })
                                            .collect(),
                                    ),
                                )
                        })
                        .collect(),
                ),
            )
    }

    fn compute_checksum(&self) -> String {
        fnv1a_hex(self.payload_json().to_string().as_bytes())
    }

    pub fn to_json(&self) -> Value {
        self.payload_json().with("checksum", self.checksum.as_str())
    }

    /// Parse + verify. Version gates first (an unknown shape cannot be
    /// checksummed meaningfully), then the seal, then bucket validity.
    pub fn from_json(v: &Value) -> Result<FrontierManifest> {
        let version = v.get("frontier_manifest_version").and_then(Value::as_i64).unwrap_or(0);
        if version != FRONTIER_MANIFEST_VERSION {
            return Err(Error::Artifact(format!(
                "frontier manifest version {version} unsupported (want {FRONTIER_MANIFEST_VERSION})"
            )));
        }
        let req_str = |key: &str| -> Result<String> {
            v.get(key)
                .and_then(Value::as_str)
                .map(String::from)
                .ok_or_else(|| Error::Artifact(format!("frontier manifest missing {key}")))
        };
        let req_usize = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| Error::Artifact(format!("frontier manifest missing {key}")))
        };
        let buckets_json = v
            .get("buckets")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Artifact("frontier manifest missing buckets".into()))?;
        let mut buckets = Vec::with_capacity(buckets_json.len());
        for b in buckets_json {
            let points_json = b
                .get("points")
                .and_then(Value::as_arr)
                .ok_or_else(|| Error::Artifact("frontier bucket missing points".into()))?;
            let mut points = Vec::with_capacity(points_json.len());
            for p in points_json {
                let field = |key: &str| -> Result<String> {
                    p.get(key)
                        .and_then(Value::as_str)
                        .map(String::from)
                        .ok_or_else(|| Error::Artifact(format!("frontier point missing {key}")))
                };
                let num = |key: &str| -> Result<f64> {
                    p.get(key)
                        .and_then(Value::as_f64)
                        .ok_or_else(|| Error::Artifact(format!("frontier point missing {key}")))
                };
                let schedule =
                    schedule_from_spec(&field("schedule_kind")?, &field("schedule_spec")?)?;
                let refresh = p.get("refresh_every").and_then(Value::as_usize).unwrap_or(0);
                let strategy = GuidanceStrategy::parse(&field("strategy")?, refresh)?;
                points.push(FrontierPoint {
                    label: field("label")?,
                    schedule,
                    strategy,
                    ssim: num("ssim")?,
                    cost_ms: num("cost_ms")?,
                });
            }
            buckets.push(FrontierBucket {
                steps: b
                    .get("steps")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| Error::Artifact("frontier bucket missing steps".into()))?,
                full_cost_ms: b
                    .get("full_cost_ms")
                    .and_then(Value::as_f64)
                    .ok_or_else(|| Error::Artifact("frontier bucket missing full_cost_ms".into()))?,
                points,
            });
        }
        let m = FrontierManifest {
            version,
            tool_version: req_str("tool_version")?,
            backend: req_str("backend")?,
            preset: req_str("preset")?,
            model_fingerprint: req_str("model_fingerprint")?,
            resolution: req_usize("resolution")?,
            guidance_scale: v
                .get("guidance_scale")
                .and_then(Value::as_f64)
                .ok_or_else(|| Error::Artifact("frontier manifest missing guidance_scale".into()))?
                as f32,
            candidates_swept: req_usize("candidates_swept")?,
            buckets,
            checksum: req_str("checksum")?,
        };
        let computed = m.compute_checksum();
        if computed != m.checksum {
            return Err(Error::Artifact(format!(
                "frontier manifest checksum mismatch: file says {}, content hashes to {computed} \
                 — the frontier was tampered with or hand-edited; retune instead",
                m.checksum
            )));
        }
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<FrontierManifest> {
        Self::from_json(&json::from_file(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| Error::io(format!("writing {}", path.display()), e))
    }
}

/// The offline sweep shape: which grammar points [`tune_frontier`]
/// evaluates per steps bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct TunerConfig {
    /// Step counts to tune a frontier for.
    pub steps_buckets: Vec<usize>,
    /// `Last`-window fractions, each swept as cond-only and hold-reuse.
    pub fractions: Vec<f64>,
    /// Cadence periods (guide every k-th step, hold-reuse between).
    pub cadences: Vec<usize>,
    /// Guided intervals `(lo, hi)` (optimized outside, hold-reuse).
    pub intervals: Vec<(f64, f64)>,
    /// Refresh cadence for every hold-reuse candidate.
    pub refresh_every: usize,
    /// Guidance scale candidates are compiled and scored at.
    pub guidance_scale: f32,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            steps_buckets: vec![20, 50],
            fractions: vec![0.2, 0.4, 0.6, 0.8],
            cadences: vec![2, 3, 4],
            intervals: vec![(0.0, 0.5), (0.25, 0.75)],
            refresh_every: 4,
            guidance_scale: 7.5,
        }
    }
}

impl TunerConfig {
    /// The CI / smoke sweep: one small bucket, fewer candidates.
    pub fn fast() -> TunerConfig {
        TunerConfig {
            steps_buckets: vec![12],
            fractions: vec![0.25, 0.5, 0.75],
            cadences: vec![2, 4],
            intervals: vec![(0.0, 0.5)],
            refresh_every: 4,
            guidance_scale: 7.5,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps_buckets.is_empty() {
            return Err(Error::Config("tuner needs at least one steps bucket".into()));
        }
        if self.steps_buckets.iter().any(|&n| n == 0) {
            return Err(Error::Config("tuner steps buckets must be >= 1".into()));
        }
        if !self.guidance_scale.is_finite() || self.guidance_scale < 0.0 {
            return Err(Error::Config(format!(
                "tuner guidance scale {} must be finite and >= 0",
                self.guidance_scale
            )));
        }
        Ok(())
    }

    /// The candidate enumeration, full-CFG baseline first. Every entry
    /// validates through [`GuidanceSchedule::validate`] at compile time.
    pub fn candidates(&self) -> Vec<(GuidanceSchedule, GuidanceStrategy)> {
        let hold =
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: self.refresh_every };
        let mut out = vec![(GuidanceSchedule::none(), GuidanceStrategy::CondOnly)];
        for &f in &self.fractions {
            out.push((GuidanceSchedule::Window(WindowSpec::last(f)), GuidanceStrategy::CondOnly));
            out.push((GuidanceSchedule::Window(WindowSpec::last(f)), hold));
        }
        for &k in &self.cadences {
            out.push((GuidanceSchedule::Cadence { every: k }, hold));
        }
        for &(lo, hi) in &self.intervals {
            out.push((GuidanceSchedule::Interval { lo, hi }, hold));
        }
        out
    }
}

/// Provenance sealed into the manifest — what a replica validates the
/// frontier against before trusting it (mirrors the calibrate seal).
#[derive(Debug, Clone)]
pub struct TuneProvenance {
    pub tool_version: String,
    pub backend: String,
    pub preset: String,
    pub model_fingerprint: String,
    pub resolution: usize,
}

/// Sweep the grammar, score every candidate, keep the non-dominated set
/// per bucket, seal. `score(schedule, strategy, steps)` returns the
/// candidate's SSIM against the full-CFG baseline at `steps` — a closure
/// so the guidance layer stays engine-free (`runtime::tune` supplies the
/// engine-driven scorer; tests supply analytic ones). Candidates that
/// compile to zero shed are scored 1.0 without calling the closure: an
/// identical plan is bit-identical output by the determinism invariant.
pub fn tune_frontier<F>(
    cfg: &TunerConfig,
    table: &CostTable,
    prov: &TuneProvenance,
    mut score: F,
) -> Result<FrontierManifest>
where
    F: FnMut(&GuidanceSchedule, GuidanceStrategy, usize) -> Result<f64>,
{
    cfg.validate()?;
    let candidates = cfg.candidates();
    let mut buckets = Vec::with_capacity(cfg.steps_buckets.len());
    for &steps in &cfg.steps_buckets {
        let full = GuidancePlan::compile(
            &GuidanceSchedule::none(),
            cfg.guidance_scale,
            GuidanceStrategy::CondOnly,
            steps,
        )?
        .cost_ms(table);
        let mut scored = Vec::with_capacity(candidates.len());
        for (schedule, strategy) in &candidates {
            let plan = GuidancePlan::compile(schedule, cfg.guidance_scale, *strategy, steps)?;
            let cost_ms = plan.cost_ms(table);
            let ssim = if plan.effective_fraction() == 0.0 {
                1.0
            } else {
                score(schedule, *strategy, steps)?
            };
            if !ssim.is_finite() || !(0.0..=1.0).contains(&ssim) {
                return Err(Error::Config(format!(
                    "tuner score {ssim} for {} at {steps} steps outside [0, 1]",
                    schedule.label()
                )));
            }
            scored.push(FrontierPoint {
                label: format!("{} × {}", schedule.label(), strategy.label()),
                schedule: schedule.clone(),
                strategy: *strategy,
                ssim,
                cost_ms,
            });
        }
        // Pareto prune: ascending cost, ties broken by descending SSIM
        // then label (deterministic); a point survives only when it buys
        // strictly more SSIM than everything cheaper.
        scored.sort_by(|a, b| {
            a.cost_ms
                .partial_cmp(&b.cost_ms)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.ssim.partial_cmp(&a.ssim).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.label.cmp(&b.label))
        });
        let mut points: Vec<FrontierPoint> = Vec::new();
        for p in scored {
            let improves = match points.last() {
                Some(last) => p.ssim > last.ssim,
                None => true,
            };
            if improves {
                points.push(p);
            }
        }
        let bucket = FrontierBucket { steps, full_cost_ms: full, points };
        bucket.validate().map_err(|e| Error::Config(format!("tuner produced {e}")))?;
        buckets.push(bucket);
    }
    buckets.sort_by_key(|b| b.steps);
    Ok(FrontierManifest::seal(
        prov.tool_version.clone(),
        prov.backend.clone(),
        prov.preset.clone(),
        prov.model_fingerprint.clone(),
        prov.resolution,
        cfg.guidance_scale,
        candidates.len(),
        buckets,
    ))
}

/// What [`PlanSearch::select`] hands the actuator: a frontier point plus
/// its saving under the bucket it came from.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectedPlan {
    pub schedule: GuidanceSchedule,
    pub strategy: GuidanceStrategy,
    pub ssim: f64,
    pub cost_ms: f64,
    /// `1 − cost_ms / full_cost_ms` of the matched bucket.
    pub saving: f64,
}

/// Counter snapshot for `/stats` and telemetry (mirrors
/// [`CostTable::fallback_count`]'s shared-observability discipline).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlannerSnapshot {
    /// Admission-time frontier consultations.
    pub searches: u64,
    /// Searches a bucket answered.
    pub frontier_hits: u64,
    /// Searches with no usable bucket — the caller fell back to the
    /// legacy analytic widening path.
    pub fallbacks: u64,
    /// Searches whose load-demanded saving exceeded the quality floor
    /// and was clamped to the floor's frontier point.
    pub floor_clamps: u64,
}

/// The O(1) admission-time consumer of a sealed frontier.
#[derive(Debug)]
pub struct PlanSearch {
    manifest: FrontierManifest,
    searches: AtomicU64,
    frontier_hits: AtomicU64,
    fallbacks: AtomicU64,
    floor_clamps: AtomicU64,
}

/// Equality is the sealed frontier's identity (its checksum); the search
/// counters are observability, not identity (mirrors [`CostTable`]'s
/// counter-ignoring equality).
impl PartialEq for PlanSearch {
    fn eq(&self, other: &PlanSearch) -> bool {
        self.manifest.checksum == other.manifest.checksum
    }
}

impl PlanSearch {
    /// Wrap a verified manifest; every bucket is re-validated so the hot
    /// path can binary-search without checking shape.
    pub fn new(manifest: FrontierManifest) -> Result<PlanSearch> {
        if manifest.buckets.is_empty() {
            return Err(Error::Artifact("frontier manifest has no buckets".into()));
        }
        for b in &manifest.buckets {
            b.validate()?;
        }
        Ok(PlanSearch {
            manifest,
            searches: AtomicU64::new(0),
            frontier_hits: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
            floor_clamps: AtomicU64::new(0),
        })
    }

    pub fn manifest(&self) -> &FrontierManifest {
        &self.manifest
    }

    pub fn snapshot(&self) -> PlannerSnapshot {
        PlannerSnapshot {
            searches: self.searches.load(Ordering::Relaxed),
            frontier_hits: self.frontier_hits.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            floor_clamps: self.floor_clamps.load(Ordering::Relaxed),
        }
    }

    /// The max-quality frontier point whose saving covers `needed_saving`
    /// (fraction of full-CFG cost the load demands back), never shedding
    /// past `floor_saving` (the quality floor's frontier-point budget).
    ///
    /// O(1) in the grammar: one nearest-bucket scan over the handful of
    /// tuned buckets plus one binary search over the sorted frontier —
    /// no candidate is compiled or scored here. Returns `None` (and
    /// counts a fallback) when no tuned bucket is within 2× of `steps`;
    /// the caller then uses the legacy analytic widening path.
    pub fn select(
        &self,
        steps: usize,
        needed_saving: f64,
        floor_saving: f64,
    ) -> Option<SelectedPlan> {
        self.searches.fetch_add(1, Ordering::Relaxed);
        let bucket = self
            .manifest
            .buckets
            .iter()
            .min_by(|a, b| {
                a.steps
                    .abs_diff(steps)
                    .cmp(&b.steps.abs_diff(steps))
                    .then(a.steps.cmp(&b.steps))
            })
            .filter(|b| b.steps <= steps.saturating_mul(2) && steps <= b.steps.saturating_mul(2));
        let Some(bucket) = bucket else {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        self.frontier_hits.fetch_add(1, Ordering::Relaxed);
        let floor = floor_saving.clamp(0.0, 1.0);
        let mut needed = needed_saving.clamp(0.0, 1.0);
        if needed > floor {
            self.floor_clamps.fetch_add(1, Ordering::Relaxed);
            needed = floor;
        }
        // saving decreases along the cost-ascending frontier, so "max
        // quality with saving >= needed" is the most expensive point at
        // or under the cost ceiling; when even the cheapest point saves
        // too little, degrade to it (max available saving).
        let ceiling = bucket.full_cost_ms * (1.0 - needed);
        let idx = bucket.points.partition_point(|p| p.cost_ms <= ceiling + 1e-9);
        let p = &bucket.points[idx.saturating_sub(1)];
        Some(SelectedPlan {
            schedule: p.schedule.clone(),
            strategy: p.strategy,
            ssim: p.ssim,
            cost_ms: p.cost_ms,
            saving: p.saving(bucket.full_cost_ms),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic engine-free quality model for tuner tests: quality
    /// falls with effective shed, reuse strategies degrade slower than
    /// cond-only (the fig5/fig6 shape).
    fn analytic_score(
        schedule: &GuidanceSchedule,
        strategy: GuidanceStrategy,
        steps: usize,
    ) -> Result<f64> {
        let plan = GuidancePlan::compile(schedule, 7.5, strategy, steps)?;
        let f = plan.effective_fraction();
        let penalty = match strategy {
            GuidanceStrategy::CondOnly => 0.30,
            GuidanceStrategy::Reuse { .. } => 0.12,
        };
        Ok((1.0 - penalty * f * f).clamp(0.0, 1.0))
    }

    fn prov() -> TuneProvenance {
        TuneProvenance {
            tool_version: "0.2.0".into(),
            backend: "synthetic".into(),
            preset: "t".into(),
            model_fingerprint: "00000000deadbeef".into(),
            resolution: 8,
        }
    }

    fn tuned() -> FrontierManifest {
        let table = CostTable::proportional(1.0, &[1, 2, 4]);
        tune_frontier(&TunerConfig::default(), &table, &prov(), analytic_score).unwrap()
    }

    #[test]
    fn frontier_is_strictly_non_dominated_and_anchored() {
        let m = tuned();
        assert_eq!(m.buckets.len(), 2);
        for b in &m.buckets {
            b.validate().unwrap();
            // baseline anchor: the most expensive point is full CFG
            let last = b.points.last().unwrap();
            assert_eq!(last.ssim, 1.0);
            assert!((last.cost_ms - b.full_cost_ms).abs() < 1e-9);
            assert!(b.points.first().unwrap().cost_ms < b.full_cost_ms);
        }
        assert_eq!(m.candidates_swept, TunerConfig::default().candidates().len());
    }

    #[test]
    fn schedule_specs_round_trip_every_kind() {
        use super::super::plan::Segment;
        for sched in [
            GuidanceSchedule::none(),
            GuidanceSchedule::Window(WindowSpec::last(0.35)),
            GuidanceSchedule::Window(WindowSpec::first(0.2)),
            GuidanceSchedule::Window(WindowSpec::at_offset(0.125, 0.5)),
            GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 },
            GuidanceSchedule::Cadence { every: 4 },
            GuidanceSchedule::Segments(vec![
                Segment::optimized(0.0, 0.2),
                Segment::dual(0.4, 0.6),
            ]),
        ] {
            let (kind, spec) = schedule_to_spec(&sched);
            let back = schedule_from_spec(kind, &spec).unwrap();
            assert_eq!(back, sched, "{kind} {spec}");
        }
        assert!(schedule_from_spec("window", "0.3").is_err());
        assert!(schedule_from_spec("cadence", "x").is_err());
        assert!(schedule_from_spec("bogus", "1").is_err());
    }

    #[test]
    fn manifest_round_trips_bit_exact() {
        let m = tuned();
        let text = m.to_json().to_string();
        let back = FrontierManifest::from_json(&json::from_str(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.to_json().to_string(), text, "canonical serialization");
    }

    #[test]
    fn tampered_manifest_rejected_with_typed_error() {
        let m = tuned();
        let text = m.to_json().to_string();
        let needle = format!("\"ssim\":{}", m.buckets[0].points[0].ssim);
        let tampered = text.replacen(&needle, "\"ssim\":0.999999", 1);
        assert_ne!(text, tampered, "tamper target must exist");
        let err = FrontierManifest::from_json(&json::from_str(&tampered).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn version_gate_before_checksum() {
        let m = tuned();
        let text = m
            .to_json()
            .to_string()
            .replace("\"frontier_manifest_version\":1", "\"frontier_manifest_version\":9");
        let err = FrontierManifest::from_json(&json::from_str(&text).unwrap()).unwrap_err();
        assert!(err.to_string().contains("version 9 unsupported"), "{err}");
    }

    #[test]
    fn select_is_budget_monotone_and_floor_clamped() {
        let ps = PlanSearch::new(tuned()).unwrap();
        let floor = 0.5;
        // the cheapest point's saving bounds what any demand can get
        let max_saving = ps.select(50, 1.0, 1.0).unwrap().saving;
        let mut prev_ssim = f64::NEG_INFINITY;
        // needed saving falling 0.9 -> 0.0 == deadline budget rising
        for i in (0..=18).rev() {
            let needed = i as f64 * 0.05;
            let sel = ps.select(50, needed, floor).expect("bucket hit");
            assert!(sel.ssim >= prev_ssim, "more budget must never lose SSIM");
            prev_ssim = sel.ssim;
            // below the floor and within the frontier's reach, the
            // selected plan must actually cover the demanded saving
            if needed <= floor && needed <= max_saving {
                assert!(sel.saving + 1e-9 >= needed, "needed {needed} got {}", sel.saving);
            }
        }
        let snap = ps.snapshot();
        assert_eq!(snap.searches, 20);
        assert_eq!(snap.frontier_hits, 20);
        assert_eq!(snap.fallbacks, 0);
        // needed 0.55..0.9 exceeded the 0.5 floor
        assert_eq!(snap.floor_clamps, 8);
        // zero demand returns the full-CFG anchor
        let idle = ps.select(50, 0.0, floor).unwrap();
        assert_eq!(idle.ssim, 1.0);
        assert_eq!(idle.saving, 0.0);
    }

    #[test]
    fn select_falls_back_off_the_tuned_range() {
        let ps = PlanSearch::new(tuned()).unwrap();
        // buckets are 20 and 50; 8 steps is out of 2x range of both
        assert!(ps.select(8, 0.3, 0.5).is_none());
        assert!(ps.select(500, 0.3, 0.5).is_none());
        // 30 steps maps to the nearest bucket (20, ties go lower)
        assert!(ps.select(30, 0.3, 0.5).is_some());
        let snap = ps.snapshot();
        assert_eq!(snap.searches, 3);
        assert_eq!(snap.frontier_hits, 1);
        assert_eq!(snap.fallbacks, 2);
    }

    #[test]
    fn tuning_is_deterministic() {
        let a = tuned().to_json().to_string();
        let b = tuned().to_json().to_string();
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_frontiers_are_refused() {
        let m = tuned();
        let empty = FrontierManifest::seal("0.2.0", "s", "t", "0", 8, 7.5, 0, vec![]);
        assert!(matches!(PlanSearch::new(empty).unwrap_err(), Error::Artifact(_)));
        // a dominated pair fails bucket validation
        let mut bad = m.clone();
        let p = bad.buckets[0].points[0].clone();
        bad.buckets[0].points.insert(1, p);
        assert!(FrontierBucket::validate(&bad.buckets[0]).is_err());
    }
}
