//! Adaptive selective guidance — the paper's future-work direction.
//!
//! The static policy fixes the optimization window ahead of time. The
//! paper's §2 observation suggests something stronger: the unconditional
//! pass is skippable exactly when it stops mattering, i.e. when the
//! *guidance delta* `‖ε_c − ε_u‖ / ‖ε_u‖` becomes small. This controller
//! measures that delta on every dual iteration and switches to cond-only
//! once the observed delta stays below a threshold for `patience`
//! consecutive iterations — an online version of "the later iterations
//! only refine detail".
//!
//! Properties:
//! * never skips during the first `min_dual_fraction` of the loop (layout
//!   formation is protected, per Figure 1);
//! * optional re-probing: every `probe_every` iterations after switching,
//!   one dual iteration re-measures the delta and re-enables CFG if it
//!   grew back above the threshold (hysteresis factor 2x).
//!
//! The engine drives this via [`AdaptiveController::decide`] +
//! [`AdaptiveController::observe_delta`]; the ablation bench compares the
//! latency/quality frontier against static windows.

/// Online skip controller for one trajectory.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    /// Relative guidance-delta threshold below which the uncond pass is
    /// considered dead weight.
    pub threshold: f64,
    /// Consecutive below-threshold dual iterations required to switch.
    pub patience: usize,
    /// Fraction of the loop that always runs dual (protects layout).
    pub min_dual_fraction: f64,
    /// After switching, re-probe with a dual iteration this often
    /// (0 = never re-probe).
    pub probe_every: usize,
    // --- state ---
    below_count: usize,
    skipping: bool,
    since_probe: usize,
    deltas: Vec<f64>,
}

impl Default for AdaptiveController {
    fn default() -> Self {
        AdaptiveController {
            threshold: 0.05,
            patience: 2,
            min_dual_fraction: 0.3,
            probe_every: 8,
            below_count: 0,
            skipping: false,
            since_probe: 0,
            deltas: Vec::new(),
        }
    }
}

/// What the controller wants for iteration `i`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdaptiveDecision {
    /// Run both passes and report the delta via `observe_delta`.
    Dual,
    /// Run the conditional pass only.
    CondOnly,
}

impl AdaptiveController {
    pub fn new(threshold: f64, patience: usize, min_dual_fraction: f64) -> Self {
        AdaptiveController {
            threshold,
            patience: patience.max(1),
            min_dual_fraction: min_dual_fraction.clamp(0.0, 1.0),
            ..Default::default()
        }
    }

    /// Decide iteration `i` of `n`.
    pub fn decide(&mut self, i: usize, n: usize) -> AdaptiveDecision {
        if (i as f64) < self.min_dual_fraction * n as f64 {
            return AdaptiveDecision::Dual;
        }
        if self.skipping {
            self.since_probe += 1;
            if self.probe_every > 0 && self.since_probe >= self.probe_every {
                self.since_probe = 0;
                return AdaptiveDecision::Dual; // re-probe
            }
            return AdaptiveDecision::CondOnly;
        }
        AdaptiveDecision::Dual
    }

    /// Report the relative guidance delta measured on a dual iteration.
    pub fn observe_delta(&mut self, delta: f64) {
        self.deltas.push(delta);
        if self.skipping {
            // re-probe result: hysteresis — only re-enable when the delta
            // grew well above the switch-off threshold
            if delta > 2.0 * self.threshold {
                self.skipping = false;
                self.below_count = 0;
            }
            return;
        }
        if delta < self.threshold {
            self.below_count += 1;
            if self.below_count >= self.patience {
                self.skipping = true;
                self.since_probe = 0;
            }
        } else {
            self.below_count = 0;
        }
    }

    /// Observed delta history (for diagnostics / benches).
    pub fn deltas(&self) -> &[f64] {
        &self.deltas
    }

    pub fn is_skipping(&self) -> bool {
        self.skipping
    }

    /// Reset for a fresh trajectory.
    pub fn reset(&mut self) {
        self.below_count = 0;
        self.skipping = false;
        self.since_probe = 0;
        self.deltas.clear();
    }
}

/// Relative guidance delta `‖ε_c − ε_u‖ / ‖ε_u‖` on host buffers.
pub fn guidance_delta(eps_cond: &[f32], eps_uncond: &[f32]) -> f64 {
    assert_eq!(eps_cond.len(), eps_uncond.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&c, &u) in eps_cond.iter().zip(eps_uncond) {
        let d = (c - u) as f64;
        num += d * d;
        den += (u as f64) * (u as f64);
    }
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn protects_early_iterations() {
        let mut c = AdaptiveController::new(0.5, 1, 0.3);
        // even huge thresholds never skip in the first 30%
        for i in 0..3 {
            assert_eq!(c.decide(i, 10), AdaptiveDecision::Dual);
            c.observe_delta(0.0);
        }
        assert_eq!(c.decide(3, 10), AdaptiveDecision::CondOnly);
    }

    #[test]
    fn switches_after_patience() {
        let mut c = AdaptiveController::new(0.1, 3, 0.0);
        for i in 0..2 {
            assert_eq!(c.decide(i, 100), AdaptiveDecision::Dual);
            c.observe_delta(0.01);
            assert!(!c.is_skipping(), "switched too early at {i}");
        }
        assert_eq!(c.decide(2, 100), AdaptiveDecision::Dual);
        c.observe_delta(0.01);
        assert!(c.is_skipping());
        assert_eq!(c.decide(3, 100), AdaptiveDecision::CondOnly);
    }

    #[test]
    fn above_threshold_resets_patience() {
        let mut c = AdaptiveController::new(0.1, 2, 0.0);
        c.decide(0, 10);
        c.observe_delta(0.01);
        c.decide(1, 10);
        c.observe_delta(0.5); // resets
        c.decide(2, 10);
        c.observe_delta(0.01);
        assert!(!c.is_skipping());
        c.decide(3, 10);
        c.observe_delta(0.01);
        assert!(c.is_skipping());
    }

    #[test]
    fn reprobe_reenables_on_delta_growth() {
        let mut c = AdaptiveController { probe_every: 2, ..AdaptiveController::new(0.1, 1, 0.0) };
        c.decide(0, 100);
        c.observe_delta(0.01);
        assert!(c.is_skipping());
        assert_eq!(c.decide(1, 100), AdaptiveDecision::CondOnly);
        // second skipped iteration triggers a probe
        assert_eq!(c.decide(2, 100), AdaptiveDecision::Dual);
        c.observe_delta(0.5); // grew back above 2x threshold
        assert!(!c.is_skipping());
        assert_eq!(c.decide(3, 100), AdaptiveDecision::Dual);
    }

    #[test]
    fn hysteresis_band_keeps_skipping() {
        let mut c = AdaptiveController { probe_every: 1, ..AdaptiveController::new(0.1, 1, 0.0) };
        c.decide(0, 100);
        c.observe_delta(0.05);
        assert!(c.is_skipping());
        // probe measures 0.15: above threshold but below 2x -> keep skipping
        assert_eq!(c.decide(1, 100), AdaptiveDecision::Dual);
        c.observe_delta(0.15);
        assert!(c.is_skipping());
    }

    #[test]
    fn reset_clears_state() {
        let mut c = AdaptiveController::new(0.1, 1, 0.0);
        c.decide(0, 10);
        c.observe_delta(0.01);
        assert!(c.is_skipping());
        c.reset();
        assert!(!c.is_skipping());
        assert!(c.deltas().is_empty());
    }

    #[test]
    fn guidance_delta_math() {
        assert_eq!(guidance_delta(&[1.0, 1.0], &[1.0, 1.0]), 0.0);
        // ||c-u||/||u|| = ||(1,0)-(0,0)... den 0
        assert!(guidance_delta(&[1.0], &[0.0]).is_infinite());
        assert_eq!(guidance_delta(&[0.0], &[0.0]), 0.0);
        let d = guidance_delta(&[2.0, 0.0], &[1.0, 0.0]);
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn never_skips_with_infinite_threshold_zero() {
        forall("adaptive never-skip", 50, |g| {
            let mut c = AdaptiveController::new(0.0, 1, 0.0);
            let n = g.usize_in(1, 50);
            for i in 0..n {
                if c.decide(i, n) == AdaptiveDecision::Dual {
                    c.observe_delta(g.f64_in(1e-6, 10.0));
                }
            }
            assert!(!c.is_skipping(), "threshold 0 must never skip");
        });
    }

    #[test]
    fn decisions_respect_min_dual_fraction_property() {
        forall("adaptive min-dual", 100, |g| {
            let frac = g.f64_in(0.0, 1.0);
            let n = g.usize_in(1, 100);
            let mut c = AdaptiveController::new(1e9, 1, frac); // skip asap
            let mut first_skip = None;
            for i in 0..n {
                match c.decide(i, n) {
                    AdaptiveDecision::Dual => c.observe_delta(0.0),
                    AdaptiveDecision::CondOnly => {
                        first_skip.get_or_insert(i);
                    }
                }
            }
            if let Some(i) = first_skip {
                assert!(
                    i as f64 >= frac * n as f64,
                    "skipped at {i} before min dual fraction {frac} of {n}"
                );
            }
        });
    }
}
