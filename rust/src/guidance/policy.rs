//! The validated guidance policy: a (schedule, scale, strategy) triple
//! that compiles into the per-step [`GuidancePlan`] the engine executes.

use super::plan::{GuidancePlan, GuidanceSchedule};
use super::strategy::{GuidanceStrategy, ReuseKind};
use super::window::WindowSpec;
use crate::error::Result;

/// What the engine must run for one denoising iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GuidanceMode {
    /// Full CFG: two UNet evaluations + Eq.-1 combine with scale `s`.
    Dual { scale: f32 },
    /// Optimized: conditional evaluation only (`eps_hat = eps_c`).
    CondOnly,
    /// Optimized with guidance kept: conditional evaluation + Eq.-1
    /// combine against a cached/extrapolated unconditional eps.
    Reuse { scale: f32, kind: ReuseKind },
    /// Unguided sampling (guidance scale == 1 collapses Eq. 1 to the
    /// conditional term; skip the dead uncond pass *everywhere*).
    Unguided,
}

impl GuidanceMode {
    /// UNet evaluations this mode costs.
    pub fn unet_evals(&self) -> usize {
        match self {
            GuidanceMode::Dual { .. } => 2,
            GuidanceMode::CondOnly | GuidanceMode::Reuse { .. } | GuidanceMode::Unguided => 1,
        }
    }
}

/// The selective-guidance policy: a validated (schedule, scale,
/// strategy) triple. Since the plan IR landed (DESIGN.md §10) this is a
/// thin compiler front-end — all per-step decision logic lives in
/// [`GuidancePlan::compile`]; `decide`/`total_unet_evals` are
/// conveniences that compile and query a plan.
#[derive(Debug, Clone, PartialEq)]
pub struct SelectiveGuidancePolicy {
    schedule: GuidanceSchedule,
    guidance_scale: f32,
    strategy: GuidanceStrategy,
}

impl SelectiveGuidancePolicy {
    /// The paper's policy: optimized iterations drop guidance entirely.
    pub fn new(window: WindowSpec, guidance_scale: f32) -> Result<Self> {
        Self::with_strategy(window, guidance_scale, GuidanceStrategy::CondOnly)
    }

    /// A windowed policy whose optimized iterations follow `strategy`.
    pub fn with_strategy(
        window: WindowSpec,
        guidance_scale: f32,
        strategy: GuidanceStrategy,
    ) -> Result<Self> {
        Self::with_schedule(GuidanceSchedule::Window(window), guidance_scale, strategy)
    }

    /// A policy over any [`GuidanceSchedule`] (windows, segments,
    /// limited intervals, cadences).
    pub fn with_schedule(
        schedule: GuidanceSchedule,
        guidance_scale: f32,
        strategy: GuidanceStrategy,
    ) -> Result<Self> {
        // compiling a zero-step plan runs every validation path
        GuidancePlan::compile(&schedule, guidance_scale, strategy, 0)?;
        Ok(SelectiveGuidancePolicy { schedule, guidance_scale, strategy })
    }

    /// Full CFG at the SD default scale of 7.5.
    pub fn baseline() -> Self {
        SelectiveGuidancePolicy::new(WindowSpec::none(), 7.5).unwrap()
    }

    /// The contiguous window for `Window` schedules; `WindowSpec::none()`
    /// for the richer schedule kinds (use [`Self::schedule`] for those).
    pub fn window(&self) -> WindowSpec {
        match &self.schedule {
            GuidanceSchedule::Window(w) => *w,
            _ => WindowSpec::none(),
        }
    }

    pub fn schedule(&self) -> &GuidanceSchedule {
        &self.schedule
    }

    pub fn guidance_scale(&self) -> f32 {
        self.guidance_scale
    }

    pub fn strategy(&self) -> GuidanceStrategy {
        self.strategy
    }

    /// Compile the per-step plan for an `n`-step loop. Infallible for a
    /// constructed policy (construction validated the triple).
    pub fn plan(&self, n: usize) -> GuidancePlan {
        GuidancePlan::compile(&self.schedule, self.guidance_scale, self.strategy, n)
            .expect("validated policy must compile")
    }

    /// Decide iteration `i` of an `n`-step loop (compiles a plan; hot
    /// paths should compile once via [`Self::plan`] instead).
    pub fn decide(&self, i: usize, n: usize) -> GuidanceMode {
        debug_assert!(i < n, "iteration {i} out of range for {n}-step loop");
        self.plan(n).mode(i)
    }

    /// Total UNet evaluations for an `n`-step trajectory.
    pub fn total_unet_evals(&self, n: usize) -> usize {
        self.plan(n).total_unet_evals()
    }

    /// Copy with a different guidance scale (the §3.4 retuning path).
    pub fn with_scale(&self, scale: f32) -> Result<Self> {
        SelectiveGuidancePolicy::with_schedule(self.schedule.clone(), scale, self.strategy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn baseline_all_dual() {
        let p = SelectiveGuidancePolicy::baseline();
        for i in 0..50 {
            assert_eq!(p.decide(i, 50), GuidanceMode::Dual { scale: 7.5 });
        }
        assert_eq!(p.total_unet_evals(50), 100);
    }

    #[test]
    fn last20_matches_paper() {
        let p = SelectiveGuidancePolicy::new(WindowSpec::last(0.2), 7.5).unwrap();
        // first 40 dual, last 10 cond-only => 40*2 + 10 = 90 evals
        assert_eq!(p.total_unet_evals(50), 90);
        assert_eq!(p.decide(39, 50), GuidanceMode::Dual { scale: 7.5 });
        assert_eq!(p.decide(40, 50), GuidanceMode::CondOnly);
    }

    #[test]
    fn scale_one_is_unguided_everywhere() {
        let p = SelectiveGuidancePolicy::new(WindowSpec::none(), 1.0).unwrap();
        for i in 0..10 {
            assert_eq!(p.decide(i, 10), GuidanceMode::Unguided);
        }
        assert_eq!(p.total_unet_evals(10), 10);
    }

    #[test]
    fn eval_counts_exact_for_all_policies() {
        forall("policy eval counts", 200, |g| {
            let n = g.usize_in(1, 200);
            let f = g.f64_in(0.0, 1.0);
            let s = g.f32_in(1.5, 15.0);
            let p = SelectiveGuidancePolicy::new(WindowSpec::last(f), s).unwrap();
            let k = WindowSpec::last(f).optimized_count(n);
            assert_eq!(p.total_unet_evals(n), 2 * n - k);
        });
    }

    #[test]
    fn decide_is_pure() {
        let p = SelectiveGuidancePolicy::new(WindowSpec::last(0.3), 9.0).unwrap();
        for i in 0..20 {
            assert_eq!(p.decide(i, 20), p.decide(i, 20));
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(SelectiveGuidancePolicy::new(WindowSpec::last(2.0), 7.5).is_err());
        assert!(SelectiveGuidancePolicy::new(WindowSpec::none(), f32::NAN).is_err());
        assert!(SelectiveGuidancePolicy::new(WindowSpec::none(), -1.0).is_err());
        assert!(SelectiveGuidancePolicy::with_schedule(
            GuidanceSchedule::Cadence { every: 0 },
            7.5,
            GuidanceStrategy::CondOnly
        )
        .is_err());
    }

    #[test]
    fn with_scale_keeps_window() {
        let p = SelectiveGuidancePolicy::new(WindowSpec::last(0.4), 7.5).unwrap();
        let q = p.with_scale(9.6).unwrap();
        assert_eq!(q.window(), WindowSpec::last(0.4));
        assert_eq!(q.guidance_scale(), 9.6);
    }

    #[test]
    fn mode_eval_counts() {
        assert_eq!(GuidanceMode::Dual { scale: 7.5 }.unet_evals(), 2);
        assert_eq!(GuidanceMode::CondOnly.unet_evals(), 1);
        assert_eq!(GuidanceMode::Reuse { scale: 7.5, kind: ReuseKind::Hold }.unet_evals(), 1);
        assert_eq!(GuidanceMode::Unguided.unet_evals(), 1);
    }

    #[test]
    fn reuse_policy_mode_sequence() {
        // last 40% of 10 steps, hold/2: steps 0..6 dual, then R R D R
        let p = SelectiveGuidancePolicy::with_strategy(
            WindowSpec::last(0.4),
            7.5,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 },
        )
        .unwrap();
        for i in 0..6 {
            assert_eq!(p.decide(i, 10), GuidanceMode::Dual { scale: 7.5 });
        }
        assert_eq!(p.decide(6, 10), GuidanceMode::Reuse { scale: 7.5, kind: ReuseKind::Hold });
        assert_eq!(p.decide(7, 10), GuidanceMode::Reuse { scale: 7.5, kind: ReuseKind::Hold });
        assert_eq!(p.decide(8, 10), GuidanceMode::Dual { scale: 7.5 });
        assert_eq!(p.decide(9, 10), GuidanceMode::Reuse { scale: 7.5, kind: ReuseKind::Hold });
        // 6 dual + 1 refresh = 7 dual steps, 3 reuse -> 7*2 + 3 = 17
        assert_eq!(p.total_unet_evals(10), 17);
    }

    #[test]
    fn reuse_eval_counts_exact_for_all_policies() {
        forall("reuse policy eval counts", 200, |g| {
            let n = g.usize_in(1, 200);
            let f = g.f64_in(0.0, 1.0);
            let kind = if g.bool() { ReuseKind::Hold } else { ReuseKind::Extrapolate };
            let strategy = GuidanceStrategy::Reuse { kind, refresh_every: g.usize_in(0, 8) };
            let w = WindowSpec::last(f);
            let p = SelectiveGuidancePolicy::with_strategy(w, 7.5, strategy).unwrap();
            let k = w.optimized_count(n);
            let (start, _) = w.range(n);
            let single = strategy.single_pass_count(k, start);
            assert_eq!(p.total_unet_evals(n), 2 * n - single);
            // reuse is never cheaper than cond-only, never pricier than dual
            let cond = SelectiveGuidancePolicy::new(w, 7.5).unwrap();
            assert!(p.total_unet_evals(n) >= cond.total_unet_evals(n));
            assert!(p.total_unet_evals(n) <= 2 * n);
        });
    }

    #[test]
    fn scale_one_unguided_overrides_strategy() {
        let p = SelectiveGuidancePolicy::with_strategy(
            WindowSpec::last(0.5),
            1.0,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 },
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(p.decide(i, 10), GuidanceMode::Unguided);
        }
    }

    #[test]
    fn schedule_policies_compile() {
        let p = SelectiveGuidancePolicy::with_schedule(
            GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 },
            7.5,
            GuidanceStrategy::CondOnly,
        )
        .unwrap();
        assert_eq!(p.schedule(), &GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 });
        // non-window schedules report the none window (the schedule is
        // the source of truth)
        assert_eq!(p.window(), WindowSpec::none());
        assert_eq!(p.total_unet_evals(10), 16);
    }
}
