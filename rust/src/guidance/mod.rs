//! Selective guidance — the paper's contribution, as a first-class policy.
//!
//! Classifier-free guidance (Eq. 1) costs two UNet evaluations per
//! denoising iteration. The paper's proposal: on a chosen *window* of
//! iterations, skip the unconditional evaluation and use the conditional
//! noise directly, halving that iteration's UNet cost. Section 2 shows the
//! window should sit on the **last** iterations (they refine detail and
//! are least sensitive); §3 quantifies the quality/latency trade-off and
//! §3.4 adds a guidance-scale retuning trick for aggressive windows.
//!
//! This module turns that paper-text into types:
//! * [`WindowSpec`] — which fraction of the loop is optimized, and where;
//! * [`GuidanceSchedule`] — the generalized schedule grammar (windows,
//!   multi-segment schedules, limited-interval guidance, cadence);
//! * [`GuidancePlan`] — the ahead-of-time compiled per-step plan IR every
//!   layer executes and audits against (DESIGN.md §10);
//! * [`SelectiveGuidancePolicy`] — the validated (schedule, scale,
//!   strategy) triple that compiles into plans;
//! * [`GuidanceMode`] — what the engine must execute this iteration;
//! * [`GuidanceStrategy`] — what optimized iterations do instead of the
//!   second pass: drop guidance (the paper), or keep applying Eq. 1 with
//!   a cached / extrapolated unconditional eps (guidance reuse);
//! * [`CostModel`] — the analytic saving model the benches validate
//!   against (saving ≈ f/2 of UNet time, §3.3);
//! * [`CostTable`] / [`CostManifest`] — the *measured* cost model:
//!   calibrated per-step milliseconds every scheduling layer prices
//!   plans in, sealed in a checksummed manifest (DESIGN.md §15);
//! * [`tune_frontier`] / [`FrontierManifest`] / [`PlanSearch`] — the
//!   deadline-optimal plan search: an offline Pareto sweep of this whole
//!   grammar sealed into a frontier the QoS actuator consults in O(1)
//!   at admission (DESIGN.md §16);
//! * [`retuned_scale`] / [`GsTuner`] — the §3.4 guidance-scale retuning.

mod adaptive;
mod cost;
mod cost_table;
mod gs_tuning;
mod plan;
mod planner;
mod policy;
mod strategy;
mod window;

pub use adaptive::{guidance_delta, AdaptiveController, AdaptiveDecision};
pub use cost::CostModel;
pub use cost_table::{
    CostManifest, CostRow, CostTable, FallbackPolicy, StepMode, COST_MANIFEST_VERSION,
};
pub(crate) use cost_table::fnv1a_hex as cost_table_fingerprint;
pub use gs_tuning::{retuned_scale, GsTuner};
pub use plan::{GuidancePlan, GuidanceSchedule, Segment, SegmentMode, StepPlan};
pub use planner::{
    tune_frontier, FrontierBucket, FrontierManifest, FrontierPoint, PlanSearch, PlannerSnapshot,
    SelectedPlan, TuneProvenance, TunerConfig, FRONTIER_MANIFEST_VERSION,
};
pub use policy::{GuidanceMode, SelectiveGuidancePolicy};
pub use strategy::{GuidanceStrategy, ReuseKind};
pub use window::{WindowPosition, WindowSpec};

/// Configuration for the adaptive (online) skip controller — the paper's
/// future-work variant. When set on a request it supersedes the static
/// window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptiveConfig {
    /// Relative guidance-delta threshold (see [`AdaptiveController`]).
    pub threshold: f64,
    /// Consecutive below-threshold iterations before switching.
    pub patience: usize,
    /// Fraction of the loop that always runs full CFG.
    pub min_dual_fraction: f64,
    /// Re-probe cadence after switching (0 = never).
    pub probe_every: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig { threshold: 0.05, patience: 2, min_dual_fraction: 0.3, probe_every: 8 }
    }
}

impl AdaptiveConfig {
    pub fn controller(&self) -> AdaptiveController {
        let mut c = AdaptiveController::new(self.threshold, self.patience, self.min_dual_fraction);
        c.probe_every = self.probe_every;
        c
    }

    pub fn validate(&self) -> crate::error::Result<()> {
        if !self.threshold.is_finite() || self.threshold < 0.0 {
            return Err(crate::error::Error::Config(format!(
                "adaptive threshold {} must be finite and >= 0",
                self.threshold
            )));
        }
        if !(0.0..=1.0).contains(&self.min_dual_fraction) {
            return Err(crate::error::Error::Config(format!(
                "adaptive min_dual_fraction {} outside [0, 1]",
                self.min_dual_fraction
            )));
        }
        Ok(())
    }
}
