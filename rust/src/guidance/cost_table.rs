//! Measured-cost plan model: the [`CostTable`] and its checksummed
//! manifest (DESIGN.md §15).
//!
//! Every scheduling layer priced a step in analytic *units* (dual = 2
//! UNet evals, single = 1) — only proportional to wall-clock when all
//! batch shapes and backends cost the same. They don't. The cost table
//! stores **measured per-step milliseconds** keyed by (batch bucket,
//! step mode), calibrated against the loaded runtime
//! ([`crate::runtime::calibrate`]), so slot budgets, QoS deadlines and
//! cluster routing can make millisecond decisions in milliseconds.
//!
//! Lookup is deterministic: an exact calibrated bucket wins, a batch
//! between two calibrated buckets is linearly interpolated, and anything
//! outside the calibrated range falls back to the analytic price
//! (`unit_evals × analytic_unit_ms`) **and increments a counter** — the
//! fallback is never silent ([`CostTable::fallback_count`], the
//! `sg_cost_fallback_total` metric, the `/stats` cost block).
//!
//! The calibration output travels as a [`CostManifest`]: versioned,
//! carrying the calibrator version, backend, model fingerprint and grid,
//! and sealed with an FNV-1a checksum over its canonical JSON so a
//! tampered or hand-edited table is refused at load with a typed error,
//! and `runtime/artifacts.rs` can refuse a mismatched model/cost-table
//! pair.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::policy::GuidanceMode;
use crate::error::{Error, Result};
use crate::json::{self, Value};

/// Cost-manifest format version (bump on any shape change).
pub const COST_MANIFEST_VERSION: i64 = 1;

/// FNV-1a 64-bit over raw bytes — the crate's standard content hash
/// (same construction as the cache and tokenizer hashes), rendered as
/// 16 hex digits for JSON transport.
pub(crate) fn fnv1a_hex(bytes: &[u8]) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// The cost-relevant shape of a denoising step: every
/// [`GuidanceMode`] is either a *dual* step (two UNet passes) or a
/// *single* step (one pass — cond-only, reuse, unguided). The table is
/// keyed on this, not the full mode, because the combine's cost is noise
/// next to a UNet pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum StepMode {
    Dual,
    Single,
}

impl StepMode {
    /// Collapse a full [`GuidanceMode`] to its cost shape.
    pub fn of(mode: &GuidanceMode) -> StepMode {
        match mode {
            GuidanceMode::Dual { .. } => StepMode::Dual,
            _ => StepMode::Single,
        }
    }

    /// Analytic unit cost (UNet evaluations) — the pre-table currency.
    pub fn unit_evals(self) -> usize {
        match self {
            StepMode::Dual => 2,
            StepMode::Single => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            StepMode::Dual => "dual",
            StepMode::Single => "single",
        }
    }
}

/// What an uncovered (batch, mode) key does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FallbackPolicy {
    /// Price it at `unit_evals × analytic_unit_ms` and count the
    /// fallback (the conservative default — degraded, never wrong-shaped).
    Analytic,
    /// Refuse to *attach* a table that cannot cover the model's batch
    /// buckets. Coverage is validated up front
    /// ([`CostTable::validate_covers`]) so the hot-path lookup stays
    /// infallible.
    Reject,
}

impl FallbackPolicy {
    pub fn parse(s: &str) -> Result<FallbackPolicy> {
        match s {
            "analytic" => Ok(FallbackPolicy::Analytic),
            "reject" => Ok(FallbackPolicy::Reject),
            other => Err(Error::Config(format!(
                "cost fallback {other:?} must be \"analytic\" or \"reject\""
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FallbackPolicy::Analytic => "analytic",
            FallbackPolicy::Reject => "reject",
        }
    }
}

/// Measured per-step milliseconds for one (backend, preset, resolution),
/// keyed by (batch bucket, [`StepMode`]).
///
/// Clones share the fallback counter (it is the table's observability,
/// not its identity); equality ignores it for the same reason.
#[derive(Debug, Clone)]
pub struct CostTable {
    backend: String,
    preset: String,
    /// Latent resolution the measurements bind to.
    resolution: usize,
    entries: BTreeMap<(usize, StepMode), f64>,
    /// Price of one analytic UNet-eval unit — the fallback currency.
    analytic_unit_ms: f64,
    fallback: FallbackPolicy,
    /// Uncovered-key lookups priced analytically. Never silent.
    fallbacks: Arc<AtomicU64>,
}

impl PartialEq for CostTable {
    fn eq(&self, other: &Self) -> bool {
        self.backend == other.backend
            && self.preset == other.preset
            && self.resolution == other.resolution
            && self.entries == other.entries
            && self.analytic_unit_ms == other.analytic_unit_ms
            && self.fallback == other.fallback
    }
}

impl CostTable {
    pub fn new(
        backend: impl Into<String>,
        preset: impl Into<String>,
        resolution: usize,
        analytic_unit_ms: f64,
        fallback: FallbackPolicy,
    ) -> Result<CostTable> {
        if !analytic_unit_ms.is_finite() || analytic_unit_ms <= 0.0 {
            return Err(Error::Config(format!(
                "analytic_unit_ms {analytic_unit_ms} must be finite and > 0"
            )));
        }
        Ok(CostTable {
            backend: backend.into(),
            preset: preset.into(),
            resolution,
            entries: BTreeMap::new(),
            analytic_unit_ms,
            fallback,
            fallbacks: Arc::new(AtomicU64::new(0)),
        })
    }

    /// A table whose prices are *exactly* proportional to analytic units
    /// (`dual = 2 × unit_ms`, `single = 1 × unit_ms` at every bucket) —
    /// pricing with it is a pure relabeling of unit cost, which is what
    /// the equivalence suites attach to prove ms-pricing preserves every
    /// scheduling decision bit-exactly.
    pub fn proportional(unit_ms: f64, batches: &[usize]) -> CostTable {
        let mut t = CostTable::new("analytic", "analytic", 0, unit_ms, FallbackPolicy::Analytic)
            .expect("proportional unit_ms must be finite and > 0");
        for &b in batches {
            t.insert(b, StepMode::Dual, 2.0 * unit_ms).unwrap();
            t.insert(b, StepMode::Single, unit_ms).unwrap();
        }
        t
    }

    pub fn insert(&mut self, batch: usize, mode: StepMode, ms: f64) -> Result<()> {
        if batch == 0 {
            return Err(Error::Config("cost table batch bucket must be >= 1".into()));
        }
        if !ms.is_finite() || ms <= 0.0 {
            return Err(Error::Config(format!(
                "cost table entry (batch {batch}, {}) = {ms} must be finite and > 0",
                mode.name()
            )));
        }
        self.entries.insert((batch, mode), ms);
        Ok(())
    }

    pub fn backend(&self) -> &str {
        &self.backend
    }

    pub fn preset(&self) -> &str {
        &self.preset
    }

    pub fn resolution(&self) -> usize {
        self.resolution
    }

    pub fn analytic_unit_ms(&self) -> f64 {
        self.analytic_unit_ms
    }

    pub fn fallback(&self) -> FallbackPolicy {
        self.fallback
    }

    /// Distinct calibrated batch buckets, ascending.
    pub fn batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.entries.keys().map(|&(b, _)| b).collect();
        v.dedup();
        v
    }

    /// Uncovered-key lookups so far (shared across clones).
    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Resolve the measured value for (batch, mode) without touching the
    /// fallback counter: exact bucket, else linear interpolation between
    /// the bracketing calibrated buckets, else `None`.
    fn resolve(&self, batch: usize, mode: StepMode) -> Option<f64> {
        if let Some(&ms) = self.entries.get(&(batch, mode)) {
            return Some(ms);
        }
        let mut lower: Option<(usize, f64)> = None;
        let mut upper: Option<(usize, f64)> = None;
        for (&(b, m), &ms) in &self.entries {
            if m != mode {
                continue;
            }
            if b < batch {
                lower = Some((b, ms));
            } else if upper.is_none() {
                upper = Some((b, ms));
            }
        }
        let ((b0, m0), (b1, m1)) = (lower?, upper?);
        // deterministic linear interpolation between the bracketing
        // buckets — bounded by them, monotone when the table is
        let t = (batch - b0) as f64 / (b1 - b0) as f64;
        Some(m0 + (m1 - m0) * t)
    }

    /// Is (batch, mode) covered without analytic fallback?
    pub fn covers(&self, batch: usize, mode: StepMode) -> bool {
        self.resolve(batch, mode).is_some()
    }

    /// `FallbackPolicy::Reject` tables must prove coverage of every
    /// bucket the runtime can ask for **before** they are attached, so
    /// the hot-path lookup never needs to fail.
    pub fn validate_covers(&self, batches: &[usize]) -> Result<()> {
        if self.fallback != FallbackPolicy::Reject {
            return Ok(());
        }
        for &b in batches {
            for mode in [StepMode::Dual, StepMode::Single] {
                if !self.covers(b, mode) {
                    return Err(Error::Config(format!(
                        "cost table ({}/{}) does not cover batch {b} {} and \
                         fallback = reject — recalibrate with a wider grid or \
                         use fallback = analytic",
                        self.backend,
                        self.preset,
                        mode.name()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Measured milliseconds of one denoising step of a batch-`batch`
    /// cohort in `mode`. Uncovered keys price analytically and count
    /// ([`Self::fallback_count`]).
    pub fn step_ms(&self, batch: usize, mode: StepMode) -> f64 {
        match self.resolve(batch, mode) {
            Some(ms) => ms,
            None => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                mode.unit_evals() as f64 * self.analytic_unit_ms
            }
        }
    }

    /// Per-sample price of one step in `mode` — the scheduling currency
    /// ([`crate::guidance::GuidancePlan::cost_ms`] sums it, the
    /// continuous batcher admits against it).
    pub fn sample_step_ms(&self, mode: StepMode) -> f64 {
        self.step_ms(1, mode)
    }

    /// The measured shed ratio: the fraction of a dual step's time a
    /// single step saves, `1 − single_ms/dual_ms`. The analytic model
    /// fixes this at exactly 0.5 (one of two equal UNet passes); the QoS
    /// deadline math takes it as a parameter so measured pricing is a
    /// drop-in relabeling.
    pub fn shed_ratio(&self) -> f64 {
        let dual = self.sample_step_ms(StepMode::Dual);
        let single = self.sample_step_ms(StepMode::Single);
        if dual <= 0.0 {
            return 0.5;
        }
        (1.0 - single / dual).clamp(0.0, 1.0)
    }

    /// Measured-over-analytic price ratio of a batch-1 dual step — the
    /// `sg_cost_model_ratio` gauge, i.e. how far reality has drifted
    /// from the unit model (1.0 = the unit model was right).
    pub fn model_ratio(&self) -> f64 {
        self.sample_step_ms(StepMode::Dual) / (2.0 * self.analytic_unit_ms)
    }
}

/// One calibrated grid row of a [`CostManifest`].
#[derive(Debug, Clone, PartialEq)]
pub struct CostRow {
    pub batch: usize,
    pub dual_ms: f64,
    pub single_ms: f64,
}

/// The checksummed calibration artifact: everything needed to rebuild a
/// [`CostTable`] plus the provenance (`tool_version`, backend, model
/// fingerprint, grid shape) a replica validates before trusting it.
///
/// Sealed with FNV-1a over the canonical JSON serialization minus the
/// `checksum` field; [`CostManifest::from_json`] recomputes and compares,
/// so a one-byte tamper fails with a typed [`Error::Artifact`].
#[derive(Debug, Clone, PartialEq)]
pub struct CostManifest {
    pub version: i64,
    /// Crate version of the calibrator that produced the table.
    pub tool_version: String,
    pub backend: String,
    pub preset: String,
    /// FNV-1a fingerprint of the model shape (16 hex digits) — see
    /// `runtime::Manifest::model_fingerprint`.
    pub model_fingerprint: String,
    pub resolution: usize,
    /// Calibrated batch buckets, ascending.
    pub grid: Vec<usize>,
    /// Timing samples per grid point (median-of-N after outlier
    /// rejection).
    pub samples: usize,
    /// Leading evaluations discarded per grid point.
    pub warmup: usize,
    pub analytic_unit_ms: f64,
    pub rows: Vec<CostRow>,
    /// FNV-1a (16 hex digits) over the canonical JSON minus this field.
    pub checksum: String,
}

impl CostManifest {
    /// Build and seal a manifest (computes the checksum).
    #[allow(clippy::too_many_arguments)]
    pub fn seal(
        tool_version: impl Into<String>,
        backend: impl Into<String>,
        preset: impl Into<String>,
        model_fingerprint: impl Into<String>,
        resolution: usize,
        samples: usize,
        warmup: usize,
        analytic_unit_ms: f64,
        rows: Vec<CostRow>,
    ) -> CostManifest {
        let mut m = CostManifest {
            version: COST_MANIFEST_VERSION,
            tool_version: tool_version.into(),
            backend: backend.into(),
            preset: preset.into(),
            model_fingerprint: model_fingerprint.into(),
            resolution,
            grid: rows.iter().map(|r| r.batch).collect(),
            samples,
            warmup,
            analytic_unit_ms,
            rows,
            checksum: String::new(),
        };
        m.checksum = m.compute_checksum();
        m
    }

    /// The canonical payload — everything but the seal.
    fn payload_json(&self) -> Value {
        Value::obj()
            .with("cost_manifest_version", self.version)
            .with("tool_version", self.tool_version.as_str())
            .with("backend", self.backend.as_str())
            .with("preset", self.preset.as_str())
            .with("model_fingerprint", self.model_fingerprint.as_str())
            .with("resolution", self.resolution)
            .with("grid", self.grid.clone())
            .with("samples", self.samples)
            .with("warmup", self.warmup)
            .with("analytic_unit_ms", self.analytic_unit_ms)
            .with(
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::obj()
                                .with("batch", r.batch)
                                .with("dual_ms", r.dual_ms)
                                .with("single_ms", r.single_ms)
                        })
                        .collect(),
                ),
            )
    }

    fn compute_checksum(&self) -> String {
        fnv1a_hex(self.payload_json().to_string().as_bytes())
    }

    pub fn to_json(&self) -> Value {
        self.payload_json().with("checksum", self.checksum.as_str())
    }

    /// Parse + verify. Version gates first (an unknown shape cannot be
    /// checksummed meaningfully), then the seal, then field validity.
    pub fn from_json(v: &Value) -> Result<CostManifest> {
        let version = v.get("cost_manifest_version").and_then(Value::as_i64).unwrap_or(0);
        if version != COST_MANIFEST_VERSION {
            return Err(Error::Artifact(format!(
                "cost manifest version {version} unsupported (want {COST_MANIFEST_VERSION})"
            )));
        }
        let req_str = |key: &str| -> Result<String> {
            v.get(key)
                .and_then(Value::as_str)
                .map(String::from)
                .ok_or_else(|| Error::Artifact(format!("cost manifest missing {key}")))
        };
        let req_usize = |key: &str| -> Result<usize> {
            v.get(key)
                .and_then(Value::as_usize)
                .ok_or_else(|| Error::Artifact(format!("cost manifest missing {key}")))
        };
        let rows_json = v
            .get("rows")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Artifact("cost manifest missing rows".into()))?;
        let mut rows = Vec::with_capacity(rows_json.len());
        for r in rows_json {
            let ms = |key: &str| -> Result<f64> {
                r.get(key)
                    .and_then(Value::as_f64)
                    .ok_or_else(|| Error::Artifact(format!("cost manifest row missing {key}")))
            };
            rows.push(CostRow {
                batch: r
                    .get("batch")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| Error::Artifact("cost manifest row missing batch".into()))?,
                dual_ms: ms("dual_ms")?,
                single_ms: ms("single_ms")?,
            });
        }
        let grid = v
            .get("grid")
            .and_then(Value::as_arr)
            .ok_or_else(|| Error::Artifact("cost manifest missing grid".into()))?
            .iter()
            .map(|b| {
                b.as_usize().ok_or_else(|| Error::Artifact("cost manifest bad grid entry".into()))
            })
            .collect::<Result<Vec<usize>>>()?;
        let m = CostManifest {
            version,
            tool_version: req_str("tool_version")?,
            backend: req_str("backend")?,
            preset: req_str("preset")?,
            model_fingerprint: req_str("model_fingerprint")?,
            resolution: req_usize("resolution")?,
            grid,
            samples: req_usize("samples")?,
            warmup: req_usize("warmup")?,
            analytic_unit_ms: v
                .get("analytic_unit_ms")
                .and_then(Value::as_f64)
                .ok_or_else(|| Error::Artifact("cost manifest missing analytic_unit_ms".into()))?,
            rows,
            checksum: req_str("checksum")?,
        };
        let computed = m.compute_checksum();
        if computed != m.checksum {
            return Err(Error::Artifact(format!(
                "cost manifest checksum mismatch: file says {}, content hashes to {computed} \
                 — the table was tampered with or hand-edited; recalibrate instead",
                m.checksum
            )));
        }
        Ok(m)
    }

    pub fn load(path: &Path) -> Result<CostManifest> {
        Self::from_json(&json::from_file(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .map_err(|e| Error::io(format!("writing {}", path.display()), e))
    }

    /// Rebuild the runtime [`CostTable`] this manifest carries.
    pub fn table(&self, fallback: FallbackPolicy) -> Result<CostTable> {
        let mut t = CostTable::new(
            self.backend.clone(),
            self.preset.clone(),
            self.resolution,
            self.analytic_unit_ms,
            fallback,
        )?;
        for r in &self.rows {
            t.insert(r.batch, StepMode::Dual, r.dual_ms)?;
            t.insert(r.batch, StepMode::Single, r.single_ms)?;
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> CostTable {
        let mut t =
            CostTable::new("synthetic", "t", 8, 0.5, FallbackPolicy::Analytic).unwrap();
        t.insert(1, StepMode::Dual, 1.0).unwrap();
        t.insert(1, StepMode::Single, 0.6).unwrap();
        t.insert(4, StepMode::Dual, 2.2).unwrap();
        t.insert(4, StepMode::Single, 1.2).unwrap();
        t
    }

    #[test]
    fn exact_buckets_win() {
        let t = table();
        assert_eq!(t.step_ms(1, StepMode::Dual), 1.0);
        assert_eq!(t.step_ms(4, StepMode::Single), 1.2);
        assert_eq!(t.fallback_count(), 0);
    }

    #[test]
    fn interpolation_between_brackets() {
        let t = table();
        // batch 2 sits 1/3 of the way from bucket 1 to bucket 4
        let d = t.step_ms(2, StepMode::Dual);
        assert!((d - (1.0 + (2.2 - 1.0) / 3.0)).abs() < 1e-12, "{d}");
        assert!(d > 1.0 && d < 2.2, "bounded by brackets: {d}");
        assert_eq!(t.fallback_count(), 0);
    }

    #[test]
    fn out_of_range_falls_back_and_counts() {
        let t = table();
        // batch 8 is past the calibrated range: analytic price, counted
        assert_eq!(t.step_ms(8, StepMode::Dual), 2.0 * 0.5);
        assert_eq!(t.step_ms(8, StepMode::Single), 0.5);
        assert_eq!(t.fallback_count(), 2);
        // clones share the counter — observability, not identity
        let clone = t.clone();
        clone.step_ms(16, StepMode::Dual);
        assert_eq!(t.fallback_count(), 3);
        assert_eq!(t, clone);
    }

    #[test]
    fn reject_policy_demands_coverage_up_front() {
        let mut t = CostTable::new("synthetic", "t", 8, 0.5, FallbackPolicy::Reject).unwrap();
        t.insert(1, StepMode::Dual, 1.0).unwrap();
        t.insert(1, StepMode::Single, 0.6).unwrap();
        assert!(t.validate_covers(&[1]).is_ok());
        let err = t.validate_covers(&[1, 2]).unwrap_err();
        assert!(err.to_string().contains("fallback = reject"), "{err}");
        // analytic tables never refuse
        let a = table();
        assert!(a.validate_covers(&[1, 2, 4, 999]).is_ok());
    }

    #[test]
    fn proportional_table_is_a_pure_relabeling() {
        let t = CostTable::proportional(0.7, &[1, 2, 4]);
        for b in [1, 2, 3, 4] {
            assert_eq!(t.step_ms(b, StepMode::Dual), 1.4);
            assert_eq!(t.step_ms(b, StepMode::Single), 0.7);
        }
        assert_eq!(t.fallback_count(), 0);
        assert_eq!(t.shed_ratio(), 0.5);
        assert_eq!(t.model_ratio(), 1.0);
    }

    #[test]
    fn manifest_round_trips_bit_exact() {
        let m = CostManifest::seal(
            "0.2.0",
            "synthetic",
            "t",
            "00000000deadbeef",
            8,
            9,
            3,
            0.123456789012345,
            vec![
                CostRow { batch: 1, dual_ms: 1.0000000001, single_ms: 0.6 },
                CostRow { batch: 4, dual_ms: 2.2, single_ms: 1.2 },
            ],
        );
        let text = m.to_json().to_string();
        let back = CostManifest::from_json(&json::from_str(&text).unwrap()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.to_json().to_string(), text, "canonical serialization");
        let t = back.table(FallbackPolicy::Analytic).unwrap();
        assert_eq!(t.step_ms(1, StepMode::Dual), 1.0000000001);
    }

    #[test]
    fn tampered_manifest_rejected_with_typed_error() {
        let m = CostManifest::seal(
            "0.2.0",
            "synthetic",
            "t",
            "00000000deadbeef",
            8,
            9,
            3,
            0.5,
            vec![CostRow { batch: 1, dual_ms: 1.5, single_ms: 0.75 }],
        );
        let text = m.to_json().to_string();
        // one-byte tamper: make the dual step look cheaper
        let tampered = text.replace("\"dual_ms\":1.5", "\"dual_ms\":1.4");
        assert_ne!(text, tampered);
        let err = CostManifest::from_json(&json::from_str(&tampered).unwrap()).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err:?}");
        assert!(err.to_string().contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn version_gate_before_checksum() {
        let m = CostManifest::seal("0.2.0", "s", "t", "0", 8, 9, 3, 0.5, vec![]);
        let text = m.to_json().to_string().replace(
            "\"cost_manifest_version\":1",
            "\"cost_manifest_version\":9",
        );
        let err = CostManifest::from_json(&json::from_str(&text).unwrap()).unwrap_err();
        assert!(err.to_string().contains("version 9 unsupported"), "{err}");
    }

    #[test]
    fn step_mode_collapse() {
        use crate::guidance::ReuseKind;
        assert_eq!(StepMode::of(&GuidanceMode::Dual { scale: 7.5 }), StepMode::Dual);
        assert_eq!(StepMode::of(&GuidanceMode::CondOnly), StepMode::Single);
        assert_eq!(
            StepMode::of(&GuidanceMode::Reuse { scale: 7.5, kind: ReuseKind::Hold }),
            StepMode::Single
        );
        assert_eq!(StepMode::of(&GuidanceMode::Unguided), StepMode::Single);
        assert_eq!(StepMode::Dual.unit_evals(), 2);
        assert_eq!(StepMode::Single.unit_evals(), 1);
    }
}
