//! Guidance-reuse strategies: what an *optimized* iteration does instead
//! of the second UNet pass.
//!
//! The paper's optimized iteration drops the unconditional pass outright
//! (`eps_hat = eps_c`). Related work shows a middle ground — *Compress
//! Guidance* (Dinh et al., 2024) reuses guidance signals across steps and
//! *How Much To Guide* (Zhang et al., 2025) caches CFG terms — so the
//! binary Dual/CondOnly decision generalizes into a small lattice:
//!
//! ```text
//!   quality ▲   Dual ──────────── two passes, exact Eq. 1
//!           │   Reuse{Extrapolate} one pass + linear eps_u forecast
//!           │   Reuse{Hold}       one pass + zero-order-hold eps_u
//!           │   CondOnly ──────── one pass, guidance dropped
//!   cost    ▼   (all single-pass modes cost one UNet eval)
//! ```
//!
//! Reuse modes still apply the Eq.-1 combine, substituting a **cached**
//! unconditional eps from the last dual iteration (zero-order hold) or a
//! **linear extrapolation** from the last two dual iterations. A refresh
//! cadence (`refresh_every = m`: at most `m` consecutive reuse steps,
//! then one true dual step) re-anchors the cache; `m == 0` never
//! refreshes. The first window step falls back to Dual when no dual
//! iteration precedes the window (cold cache), which keeps the mode
//! sequence a *pure* function of `(strategy, window, step)` — the engine
//! executes exactly what [`super::SelectiveGuidancePolicy::decide`]
//! predicts, and the analytic cost model stays exact.

use super::policy::GuidanceMode;
use crate::error::{Error, Result};

/// How a reuse step estimates the unconditional eps it did not compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReuseKind {
    /// Zero-order hold: replay the eps_u of the last dual iteration.
    #[default]
    Hold,
    /// Linear extrapolation from the last two dual iterations (falls back
    /// to hold while only one anchor exists).
    Extrapolate,
}

impl ReuseKind {
    pub fn name(&self) -> &'static str {
        match self {
            ReuseKind::Hold => "hold",
            ReuseKind::Extrapolate => "extrapolate",
        }
    }
}

/// What optimized-window iterations execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GuidanceStrategy {
    /// The paper's optimization: drop guidance, `eps_hat = eps_c`.
    #[default]
    CondOnly,
    /// Keep applying Eq. 1 with a cached/extrapolated eps_u;
    /// `refresh_every = m` runs a true dual step after every `m`
    /// consecutive reuse steps (0 = never refresh).
    Reuse { kind: ReuseKind, refresh_every: usize },
}

impl GuidanceStrategy {
    /// Parse a strategy name; `refresh_every` applies to reuse variants.
    pub fn parse(name: &str, refresh_every: usize) -> Result<GuidanceStrategy> {
        match name.to_ascii_lowercase().as_str() {
            "cond-only" | "cond_only" | "drop" | "none" => Ok(GuidanceStrategy::CondOnly),
            "hold" | "cached" | "reuse" => {
                Ok(GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every })
            }
            "extrapolate" | "extrap" | "linear" => {
                Ok(GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every })
            }
            other => Err(Error::Config(format!("unknown guidance strategy {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GuidanceStrategy::CondOnly => "cond-only",
            GuidanceStrategy::Reuse { kind, .. } => kind.name(),
        }
    }

    /// Human-readable label for bench tables (e.g. "hold/4").
    pub fn label(&self) -> String {
        match self {
            GuidanceStrategy::CondOnly => "cond-only".into(),
            GuidanceStrategy::Reuse { kind, refresh_every } => {
                format!("{}/{}", kind.name(), refresh_every)
            }
        }
    }

    /// The reuse kind this strategy combines with when it consumes the
    /// *cross-request* shared uncond tier (DESIGN.md §13), or `None`
    /// when the strategy can never consume it.
    ///
    /// Only `Reuse` qualifies: it is the lattice point that substitutes
    /// a cached eps_u into the Eq.-1 combine, so a shared entry slots in
    /// exactly where the local cache would. `CondOnly` drops the
    /// combine entirely — handing it a shared eps would *change* its
    /// output and break the miss-path bit-exactness invariant. (Adaptive
    /// overlays are excluded at the engine seam, where controller state
    /// lives: their replanning never emits Reuse steps.)
    pub fn shared_consumer_kind(&self) -> Option<ReuseKind> {
        match *self {
            GuidanceStrategy::CondOnly => None,
            GuidanceStrategy::Reuse { kind, .. } => Some(kind),
        }
    }

    /// Initial window steps forced Dual because the uncond cache has no
    /// anchor: one when no dual iteration precedes the window.
    fn cold_steps(&self, prior_duals: usize) -> usize {
        match self {
            GuidanceStrategy::CondOnly => 0,
            GuidanceStrategy::Reuse { .. } => usize::from(prior_duals == 0),
        }
    }

    /// Mode for the `j`-th iteration *inside* the optimization window
    /// (`j` 0-based); `prior_duals` is the number of dual iterations that
    /// run before the window starts.
    ///
    /// This closed-form window walk is the *reference* implementation:
    /// production decisions come from [`crate::guidance::GuidancePlan`]'s
    /// compile walk (which generalizes these semantics to arbitrary
    /// optimized sets), and the plan property tests assert the two agree
    /// exactly on every window schedule.
    pub fn in_window_mode(&self, j: usize, prior_duals: usize, scale: f32) -> GuidanceMode {
        match *self {
            GuidanceStrategy::CondOnly => GuidanceMode::CondOnly,
            GuidanceStrategy::Reuse { kind, refresh_every } => {
                let cold = self.cold_steps(prior_duals);
                if j < cold {
                    return GuidanceMode::Dual { scale };
                }
                // after warm-up: runs of `m` reuse steps, then one refresh
                let j = j - cold;
                if refresh_every > 0 && (j + 1) % (refresh_every + 1) == 0 {
                    GuidanceMode::Dual { scale }
                } else {
                    GuidanceMode::Reuse { scale, kind }
                }
            }
        }
    }

    /// How many of `k` window iterations run a single UNet pass (the
    /// complement — cold-start and refresh steps — stays dual).
    pub fn single_pass_count(&self, k: usize, prior_duals: usize) -> usize {
        match *self {
            GuidanceStrategy::CondOnly => k,
            GuidanceStrategy::Reuse { refresh_every, .. } => {
                let warm = k.saturating_sub(self.cold_steps(prior_duals));
                let refreshes = if refresh_every > 0 { warm / (refresh_every + 1) } else { 0 };
                warm - refreshes
            }
        }
    }

    /// The §3.3 cost model generalized to reuse: the *effective* fraction
    /// of the loop that runs single-pass for a window of `fraction`.
    /// CondOnly converts the whole window; Reuse gives back `1/(m+1)` of
    /// it to refresh steps (cold-start ignored — this feeds the QoS
    /// service predictor, not the exact eval count).
    pub fn effective_fraction(&self, window_fraction: f64) -> f64 {
        match *self {
            GuidanceStrategy::CondOnly => window_fraction,
            GuidanceStrategy::Reuse { refresh_every, .. } => {
                if refresh_every == 0 {
                    window_fraction
                } else {
                    window_fraction * refresh_every as f64 / (refresh_every + 1) as f64
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        assert_eq!(GuidanceStrategy::parse("cond-only", 0).unwrap(), GuidanceStrategy::CondOnly);
        assert_eq!(GuidanceStrategy::parse("drop", 9).unwrap(), GuidanceStrategy::CondOnly);
        assert_eq!(
            GuidanceStrategy::parse("hold", 4).unwrap(),
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 }
        );
        assert_eq!(
            GuidanceStrategy::parse("extrapolate", 2).unwrap(),
            GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 2 }
        );
        assert!(GuidanceStrategy::parse("bogus", 0).is_err());
        assert_eq!(GuidanceStrategy::default(), GuidanceStrategy::CondOnly);
    }

    #[test]
    fn labels() {
        assert_eq!(GuidanceStrategy::CondOnly.label(), "cond-only");
        let s = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 };
        assert_eq!(s.label(), "hold/4");
        assert_eq!(s.name(), "hold");
    }

    #[test]
    fn shared_consumer_kinds() {
        // CondOnly has no combine to feed a shared eps into
        assert_eq!(GuidanceStrategy::CondOnly.shared_consumer_kind(), None);
        // Reuse consumes with its own combine kind, cadence-independent
        for m in [0, 1, 4] {
            let s = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: m };
            assert_eq!(s.shared_consumer_kind(), Some(ReuseKind::Hold));
        }
        let e = GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 };
        assert_eq!(e.shared_consumer_kind(), Some(ReuseKind::Extrapolate));
    }

    #[test]
    fn cond_only_never_dual_in_window() {
        let s = GuidanceStrategy::CondOnly;
        for j in 0..20 {
            assert_eq!(s.in_window_mode(j, 0, 7.5), GuidanceMode::CondOnly);
        }
        assert_eq!(s.single_pass_count(20, 0), 20);
    }

    #[test]
    fn reuse_refresh_cadence() {
        // m = 2, warm cache: R R D R R D R R ...
        let s = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 };
        let modes: Vec<GuidanceMode> = (0..8).map(|j| s.in_window_mode(j, 5, 7.5)).collect();
        let dual = |m: &GuidanceMode| matches!(m, GuidanceMode::Dual { .. });
        assert!(!dual(&modes[0]) && !dual(&modes[1]) && dual(&modes[2]));
        assert!(!dual(&modes[3]) && !dual(&modes[4]) && dual(&modes[5]));
        assert_eq!(s.single_pass_count(8, 5), 6);
        // m = 0: never refresh once warm
        let s0 = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 };
        assert!((0..50).all(|j| !dual(&s0.in_window_mode(j, 5, 7.5))));
        assert_eq!(s0.single_pass_count(50, 5), 50);
    }

    #[test]
    fn cold_cache_forces_one_dual() {
        let s = GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 0 };
        // no prior dual iterations: the first window step must anchor
        assert_eq!(s.in_window_mode(0, 0, 7.5), GuidanceMode::Dual { scale: 7.5 });
        assert!(matches!(s.in_window_mode(1, 0, 7.5), GuidanceMode::Reuse { .. }));
        assert_eq!(s.single_pass_count(10, 0), 9);
        // with history available, step 0 reuses immediately
        assert!(matches!(s.in_window_mode(0, 3, 7.5), GuidanceMode::Reuse { .. }));
        assert_eq!(s.single_pass_count(10, 3), 10);
    }

    #[test]
    fn single_pass_count_matches_mode_sequence() {
        use crate::testutil::prop::forall;
        forall("strategy single-pass count", 300, |g| {
            let k = g.usize_in(0, 64);
            let prior = g.usize_in(0, 3);
            let s = match g.usize_in(0, 2) {
                0 => GuidanceStrategy::CondOnly,
                1 => GuidanceStrategy::Reuse {
                    kind: ReuseKind::Hold,
                    refresh_every: g.usize_in(0, 8),
                },
                _ => GuidanceStrategy::Reuse {
                    kind: ReuseKind::Extrapolate,
                    refresh_every: g.usize_in(0, 8),
                },
            };
            let counted = (0..k)
                .filter(|&j| s.in_window_mode(j, prior, 7.5).unet_evals() == 1)
                .count();
            assert_eq!(counted, s.single_pass_count(k, prior), "{s:?} k={k} prior={prior}");
        });
    }

    #[test]
    fn effective_fraction_bounds() {
        let hold4 = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 4 };
        assert!((hold4.effective_fraction(0.5) - 0.4).abs() < 1e-12);
        assert_eq!(GuidanceStrategy::CondOnly.effective_fraction(0.5), 0.5);
        let never = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 };
        assert_eq!(never.effective_fraction(0.3), 0.3);
        // reuse never claims more single-pass work than cond-only
        for m in 0..10 {
            let s = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: m };
            assert!(s.effective_fraction(0.4) <= 0.4 + 1e-12);
        }
    }
}
