//! Optimization-window algebra: which denoising iterations drop the
//! unconditional pass.

use crate::error::{Error, Result};

/// Where in the denoising loop the optimization window sits.
///
/// Figure 1 of the paper slides a fixed-size window across the loop and
/// shows quality improving as it moves right (later iterations); the
/// recommended placement is [`WindowPosition::Last`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WindowPosition {
    /// First `fraction` of iterations (paper's worst case — layout
    /// formation is most sensitive).
    First,
    /// Centered window.
    Middle,
    /// Last `fraction` of iterations (the paper's recommendation).
    Last,
    /// Window starting at a given offset fraction in [0, 1].
    Offset(f64),
}

impl WindowPosition {
    /// Canonical name; `parse(name(x)) == x` holds for every valid
    /// position (the offset prints at full precision for that reason).
    pub fn name(&self) -> String {
        match self {
            WindowPosition::First => "first".into(),
            WindowPosition::Middle => "middle".into(),
            WindowPosition::Last => "last".into(),
            WindowPosition::Offset(o) => format!("offset({o})"),
        }
    }

    /// Parse a position name: `first` / `middle` / `last` / `offset(x)`
    /// with `x` in `[0, 1]`. The one parser every surface (TOML, CLI,
    /// wire protocol) shares, so `offset(…)` — which `name()` has always
    /// printed — round-trips everywhere instead of only three of the
    /// four variants.
    pub fn parse(s: &str) -> Result<WindowPosition> {
        match s.trim() {
            "first" => Ok(WindowPosition::First),
            "middle" => Ok(WindowPosition::Middle),
            "last" => Ok(WindowPosition::Last),
            other => {
                let inner = other
                    .strip_prefix("offset(")
                    .and_then(|rest| rest.strip_suffix(')'))
                    .ok_or_else(|| {
                        Error::Config(format!(
                            "unknown window position {other:?} (expected first, middle, \
                             last, or offset(x))"
                        ))
                    })?;
                let o: f64 = inner.trim().parse().map_err(|_| {
                    Error::Config(format!("window offset {inner:?} is not a number"))
                })?;
                if !o.is_finite() || !(0.0..=1.0).contains(&o) {
                    return Err(Error::Config(format!("window offset {o} outside [0, 1]")));
                }
                Ok(WindowPosition::Offset(o))
            }
        }
    }
}

/// A validated optimization-window specification.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowSpec {
    /// Fraction of iterations optimized, in [0, 1].
    pub fraction: f64,
    /// Placement of the window.
    pub position: WindowPosition,
}

impl WindowSpec {
    /// No optimization — the CFG baseline.
    pub fn none() -> WindowSpec {
        WindowSpec { fraction: 0.0, position: WindowPosition::Last }
    }

    /// The paper's recommended configuration: optimize the last
    /// `fraction` of iterations.
    pub fn last(fraction: f64) -> WindowSpec {
        WindowSpec { fraction, position: WindowPosition::Last }
    }

    pub fn first(fraction: f64) -> WindowSpec {
        WindowSpec { fraction, position: WindowPosition::First }
    }

    pub fn middle(fraction: f64) -> WindowSpec {
        WindowSpec { fraction, position: WindowPosition::Middle }
    }

    /// Window of size `fraction` starting at `offset` (both fractions of
    /// the loop length) — the Figure-1 sliding-window experiments.
    pub fn at_offset(offset: f64, fraction: f64) -> WindowSpec {
        WindowSpec { fraction, position: WindowPosition::Offset(offset) }
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..=1.0).contains(&self.fraction) || !self.fraction.is_finite() {
            return Err(Error::Config(format!(
                "window fraction {} outside [0, 1]",
                self.fraction
            )));
        }
        if let WindowPosition::Offset(o) = self.position {
            if !(0.0..=1.0).contains(&o) || !o.is_finite() {
                return Err(Error::Config(format!("window offset {o} outside [0, 1]")));
            }
        }
        Ok(())
    }

    /// Number of optimized iterations for an `n`-step loop: ⌊f·n⌋,
    /// matching the paper's "last K% of the iterations".
    pub fn optimized_count(&self, n: usize) -> usize {
        ((self.fraction * n as f64).floor() as usize).min(n)
    }

    /// Half-open iteration range [start, end) that is optimized.
    pub fn range(&self, n: usize) -> (usize, usize) {
        let k = self.optimized_count(n);
        if k == 0 {
            return (0, 0);
        }
        match self.position {
            WindowPosition::First => (0, k),
            WindowPosition::Last => (n - k, n),
            WindowPosition::Middle => {
                let start = (n - k) / 2;
                (start, start + k)
            }
            WindowPosition::Offset(o) => {
                let start = ((o * n as f64).round() as usize).min(n - k);
                (start, start + k)
            }
        }
    }

    /// Is iteration `i` (0-based position in the inference loop, 0 =
    /// noisiest) inside the optimization window?
    pub fn contains(&self, i: usize, n: usize) -> bool {
        let (s, e) = self.range(n);
        i >= s && i < e
    }

    /// Human-readable label used in bench tables (e.g. "last 20%").
    pub fn label(&self) -> String {
        if self.fraction == 0.0 {
            "no opt.".into()
        } else {
            format!("{} {:.0}%", self.position.name(), self.fraction * 100.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn paper_table1_counts() {
        // 50-step loop: 20/30/40/50% -> 10/15/20/25 optimized iterations
        for (f, k) in [(0.2, 10), (0.3, 15), (0.4, 20), (0.5, 25)] {
            assert_eq!(WindowSpec::last(f).optimized_count(50), k);
        }
        assert_eq!(WindowSpec::none().optimized_count(50), 0);
    }

    #[test]
    fn last_window_covers_tail() {
        let w = WindowSpec::last(0.2);
        assert_eq!(w.range(50), (40, 50));
        assert!(!w.contains(39, 50));
        assert!(w.contains(40, 50));
        assert!(w.contains(49, 50));
    }

    #[test]
    fn first_window_covers_head() {
        let w = WindowSpec::first(0.25);
        assert_eq!(w.range(48), (0, 12));
        assert!(w.contains(0, 48));
        assert!(!w.contains(12, 48));
    }

    #[test]
    fn middle_window_centered() {
        let w = WindowSpec::middle(0.5);
        assert_eq!(w.range(40), (10, 30));
    }

    #[test]
    fn offset_window_clamped() {
        // offset so late the window would overflow -> clamped to the tail
        let w = WindowSpec::at_offset(0.95, 0.25);
        let (s, e) = w.range(40);
        assert_eq!(e - s, 10);
        assert_eq!(e, 40);
    }

    #[test]
    fn figure1_sliding_windows() {
        // the four Figure-1 variants: 25% window at offsets 0/0.25/0.5/0.75
        let n = 48;
        for (off, expect_start) in [(0.0, 0), (0.25, 12), (0.5, 24), (0.75, 36)] {
            let w = WindowSpec::at_offset(off, 0.25);
            let (s, e) = w.range(n);
            assert_eq!(s, expect_start);
            assert_eq!(e - s, 12);
        }
    }

    #[test]
    fn validation() {
        assert!(WindowSpec::last(0.2).validate().is_ok());
        assert!(WindowSpec::last(-0.1).validate().is_err());
        assert!(WindowSpec::last(1.1).validate().is_err());
        assert!(WindowSpec::at_offset(2.0, 0.1).validate().is_err());
        assert!(WindowSpec::last(f64::NAN).validate().is_err());
    }

    #[test]
    fn properties_hold_for_all_specs() {
        forall("window algebra", 300, |g| {
            let n = g.usize_in(1, 200);
            let fraction = g.f64_in(0.0, 1.0);
            let pos = match g.usize_in(0, 3) {
                0 => WindowPosition::First,
                1 => WindowPosition::Middle,
                2 => WindowPosition::Last,
                _ => WindowPosition::Offset(g.f64_in(0.0, 1.0)),
            };
            let w = WindowSpec { fraction, position: pos };
            w.validate().unwrap();
            let k = w.optimized_count(n);
            assert_eq!(k, (fraction * n as f64).floor() as usize);
            let (s, e) = w.range(n);
            assert!(e <= n, "range end {e} beyond {n}");
            assert_eq!(e - s, k, "range size != optimized count");
            // contains() agrees with range() exactly
            let contained = (0..n).filter(|&i| w.contains(i, n)).count();
            assert_eq!(contained, k);
        });
    }

    #[test]
    fn labels() {
        assert_eq!(WindowSpec::none().label(), "no opt.");
        assert_eq!(WindowSpec::last(0.2).label(), "last 20%");
        assert_eq!(WindowSpec::first(0.25).label(), "first 25%");
    }

    #[test]
    fn position_parse_round_trips() {
        for pos in [
            WindowPosition::First,
            WindowPosition::Middle,
            WindowPosition::Last,
            WindowPosition::Offset(0.25),
            WindowPosition::Offset(0.0),
            WindowPosition::Offset(1.0),
        ] {
            assert_eq!(WindowPosition::parse(&pos.name()).unwrap(), pos, "{pos:?}");
        }
        forall("offset round trip", 100, |g| {
            let pos = WindowPosition::Offset(g.f64_in(0.0, 1.0));
            assert_eq!(WindowPosition::parse(&pos.name()).unwrap(), pos);
        });
    }

    #[test]
    fn position_parse_rejects_bad_input() {
        assert!(WindowPosition::parse("center").is_err());
        assert!(WindowPosition::parse("offset(1.5)").is_err());
        assert!(WindowPosition::parse("offset(-0.1)").is_err());
        assert!(WindowPosition::parse("offset(nan)").is_err());
        assert!(WindowPosition::parse("offset(abc)").is_err());
        assert!(WindowPosition::parse("offset(0.2").is_err());
        assert!(WindowPosition::parse("").is_err());
    }
}
