//! Guidance-scale retuning (§3.4 of the paper).
//!
//! Aggressive optimization windows weaken the *net* guidance applied over
//! the trajectory (optimized steps apply an effective scale of 1). The
//! paper's demonstration raises GS from 7.5 to 9.6 at a 40% window to
//! recover lost detail and leaves a systematic treatment to future work —
//! which we provide here: [`retuned_scale`] chooses the scale that keeps
//! the trajectory-averaged guidance scale equal to the baseline's, and
//! [`GsTuner`] sweeps candidate scales with a quality metric to pick the
//! best (the benches drive it with SSIM-vs-baseline).

/// Scale preserving the mean per-iteration guidance under an optimized
/// fraction `f`:
///
///   baseline mean  = s
///   optimized mean = (1-f)·s' + f·1     (optimized steps act as s = 1)
///   equate  =>  s' = (s - f) / (1 - f)
///
/// For s = 7.5, f = 0.4 this gives s' ≈ 11.8; the paper's hand-tuned 9.6
/// sits between the naive s and this bound — consistent with later steps
/// contributing less to layout. A `damping` in [0, 1] interpolates:
/// damping = 0 returns s, damping = 1 returns the full compensation.
/// The paper's (7.5 → 9.6, f = 0.4) point corresponds to damping ≈ 0.49.
pub fn retuned_scale(base_scale: f32, fraction: f64, damping: f64) -> f32 {
    assert!((0.0..=1.0).contains(&fraction), "fraction {fraction}");
    assert!((0.0..=1.0).contains(&damping), "damping {damping}");
    if fraction >= 1.0 {
        return base_scale; // everything optimized; scale is moot
    }
    let s = base_scale as f64;
    let full = (s - fraction) / (1.0 - fraction);
    (s + damping * (full - s)) as f32
}

/// Sweep-based tuner: evaluate a quality score at candidate scales and
/// return the argmax (ties -> lowest scale, favoring stability).
#[derive(Debug, Clone)]
pub struct GsTuner {
    pub candidates: Vec<f32>,
}

impl GsTuner {
    /// Candidate grid around the compensation interval
    /// [base, retuned_scale(base, f, 1)].
    pub fn around(base_scale: f32, fraction: f64, steps: usize) -> GsTuner {
        assert!(steps >= 2);
        let hi = retuned_scale(base_scale, fraction, 1.0);
        let lo = base_scale;
        let candidates = (0..steps)
            .map(|i| lo + (hi - lo) * i as f32 / (steps - 1) as f32)
            .collect();
        GsTuner { candidates }
    }

    /// Pick the candidate maximizing `score` (higher is better).
    pub fn tune(&self, mut score: impl FnMut(f32) -> f64) -> (f32, f64) {
        assert!(!self.candidates.is_empty());
        let mut best = (self.candidates[0], f64::NEG_INFINITY);
        for &c in &self.candidates {
            let s = score(c);
            if s > best.1 {
                best = (c, s);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn no_optimization_no_change() {
        assert_eq!(retuned_scale(7.5, 0.0, 1.0), 7.5);
        assert_eq!(retuned_scale(7.5, 0.4, 0.0), 7.5);
    }

    #[test]
    fn paper_point_within_interval() {
        // §3.4: f=0.4 moves 7.5 -> 9.6; our compensation interval must
        // contain that hand-tuned value.
        let full = retuned_scale(7.5, 0.4, 1.0);
        assert!(full > 9.6, "full compensation {full} should exceed 9.6");
        // damping ~0.49 reproduces the paper's number
        let mid = retuned_scale(7.5, 0.4, 0.49);
        assert!((mid - 9.6).abs() < 0.15, "damped {mid} vs paper 9.6");
    }

    #[test]
    fn full_compensation_closed_form() {
        // s'=(s-f)/(1-f): s=7.5, f=0.4 -> 7.1/0.6 ≈ 11.833
        let s = retuned_scale(7.5, 0.4, 1.0);
        assert!((s - 11.8333).abs() < 1e-3, "{s}");
    }

    #[test]
    fn monotone_in_fraction_and_damping() {
        forall("retune monotone", 200, |g| {
            let base = g.f32_in(1.5, 15.0);
            let f1 = g.f64_in(0.0, 0.8);
            let f2 = g.f64_in(f1, 0.9);
            let d = g.f64_in(0.0, 1.0);
            assert!(retuned_scale(base, f2, d) >= retuned_scale(base, f1, d) - 1e-6);
            let d2 = g.f64_in(d, 1.0);
            assert!(retuned_scale(base, f1, d2) >= retuned_scale(base, f1, d) - 1e-6);
            // never below the base scale for s > 1
            assert!(retuned_scale(base, f1, d) >= base - 1e-6);
        });
    }

    #[test]
    fn tuner_grid_spans_interval() {
        let t = GsTuner::around(7.5, 0.4, 5);
        assert_eq!(t.candidates.len(), 5);
        assert!((t.candidates[0] - 7.5).abs() < 1e-6);
        assert!((t.candidates[4] - retuned_scale(7.5, 0.4, 1.0)).abs() < 1e-6);
        assert!(t.candidates.windows(2).all(|w| w[1] > w[0]));
    }

    #[test]
    fn tuner_finds_peak() {
        let t = GsTuner { candidates: vec![1.0, 2.0, 3.0, 4.0] };
        let (best, score) = t.tune(|s| -((s - 3.0) as f64).powi(2));
        assert_eq!(best, 3.0);
        assert_eq!(score, 0.0);
    }

    #[test]
    fn tuner_tie_breaks_low() {
        let t = GsTuner { candidates: vec![1.0, 2.0, 3.0] };
        let (best, _) = t.tune(|_| 1.0);
        assert_eq!(best, 1.0);
    }
}
