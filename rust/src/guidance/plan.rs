//! The guidance-plan IR: every per-step guidance decision of a
//! trajectory, compiled ahead of time into one first-class object.
//!
//! Before this module, "which denoising iterations pay for the
//! unconditional pass" was re-derived step-by-step in five independent
//! places (engine, both coordinators, QoS actuator, QoS simulator), and
//! the window algebra could only express one contiguous window. The plan
//! IR collapses that:
//!
//! * [`GuidanceSchedule`] — the *grammar* of guided/optimized step sets.
//!   It subsumes the paper's contiguous [`WindowSpec`] and adds
//!   multi-segment schedules, **limited-interval guidance** (guide only
//!   inside `[lo, hi]` — Kynkäänniemi et al., "Applying Guidance in a
//!   Limited Interval") and **cadence/compressed guidance** (guide every
//!   k-th step, reuse in between — Dinh et al., "Compress Guidance").
//! * [`GuidancePlan`] — the compiled `Vec<StepPlan>`: one
//!   [`GuidanceMode`] per step, with cost queries (`total_unet_evals`,
//!   `remaining_cost`, `peak_remaining_cost`) and derived views
//!   (`effective_fraction`, `summary`). The engine executes the plan;
//!   the continuous batcher admits against it; QoS rewrites it; the
//!   single system-wide invariant is
//!   `executed UNet evals == plan.total_unet_evals()`.
//!
//! Compilation is **pure and deterministic**: the same
//! `(schedule, scale, strategy, steps)` always yields the same plan, so
//! a sample's trajectory is a function of its own request regardless of
//! cohort composition — the invariant the equivalence suites assert.

use super::policy::GuidanceMode;
use super::strategy::GuidanceStrategy;
use super::window::{WindowPosition, WindowSpec};
use crate::error::{Error, Result};

/// What a schedule segment forces its steps to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentMode {
    /// Full CFG (two UNet passes).
    Dual,
    /// Hand the steps to the request's optimized strategy
    /// (cond-only / reuse).
    Optimized,
}

/// One fraction range `[lo, hi)` of the loop with a forced mode.
/// Later segments override earlier ones where they overlap; steps no
/// segment covers run Dual.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub lo: f64,
    pub hi: f64,
    pub mode: SegmentMode,
}

impl Segment {
    /// An optimized segment over `[lo, hi)`.
    pub fn optimized(lo: f64, hi: f64) -> Segment {
        Segment { lo, hi, mode: SegmentMode::Optimized }
    }

    /// A forced-dual segment over `[lo, hi)`.
    pub fn dual(lo: f64, hi: f64) -> Segment {
        Segment { lo, hi, mode: SegmentMode::Dual }
    }

    fn validate(&self) -> Result<()> {
        if !self.lo.is_finite() || !self.hi.is_finite() {
            return Err(Error::Config("segment bounds must be finite".into()));
        }
        if !(0.0..=1.0).contains(&self.lo) || !(0.0..=1.0).contains(&self.hi) || self.lo > self.hi
        {
            return Err(Error::Config(format!(
                "segment [{}, {}) outside 0 <= lo <= hi <= 1",
                self.lo, self.hi
            )));
        }
        Ok(())
    }

    /// Half-open step range for an `n`-step loop (round-to-nearest on
    /// both bounds, so fraction bounds built from integer step indices
    /// resolve back to exactly those indices).
    fn idx_range(&self, n: usize) -> (usize, usize) {
        let lo = ((self.lo * n as f64).round() as usize).min(n);
        let hi = ((self.hi * n as f64).round() as usize).min(n);
        (lo, hi.max(lo))
    }
}

/// Which steps of the loop are *optimized* (single-pass per the
/// strategy) vs *guided* (full dual CFG) — the generalized window.
#[derive(Debug, Clone, PartialEq)]
pub enum GuidanceSchedule {
    /// One contiguous optimized window — the paper's schedule.
    Window(WindowSpec),
    /// Explicit segment list; uncovered steps run Dual, later segments
    /// win on overlap.
    Segments(Vec<Segment>),
    /// Limited-interval guidance: Dual only inside `[lo, hi)` (fractions
    /// of the loop), optimized everywhere else.
    Interval { lo: f64, hi: f64 },
    /// Compressed guidance: Dual on every `every`-th step (step 0, k,
    /// 2k, ...), optimized in between. `every == 1` is full CFG.
    Cadence { every: usize },
}

impl Default for GuidanceSchedule {
    fn default() -> Self {
        GuidanceSchedule::none()
    }
}

impl GuidanceSchedule {
    /// No optimization — the full-CFG baseline.
    pub fn none() -> GuidanceSchedule {
        GuidanceSchedule::Window(WindowSpec::none())
    }

    /// The paper's contiguous window.
    pub fn window(w: WindowSpec) -> GuidanceSchedule {
        GuidanceSchedule::Window(w)
    }

    /// Guide only inside `[lo, hi)` of the loop.
    pub fn interval(lo: f64, hi: f64) -> GuidanceSchedule {
        GuidanceSchedule::Interval { lo, hi }
    }

    /// Guide every `every`-th step.
    pub fn cadence(every: usize) -> GuidanceSchedule {
        GuidanceSchedule::Cadence { every }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            GuidanceSchedule::Window(w) => w.validate(),
            GuidanceSchedule::Segments(segs) => {
                for s in segs {
                    s.validate()?;
                }
                Ok(())
            }
            GuidanceSchedule::Interval { lo, hi } => {
                Segment { lo: *lo, hi: *hi, mode: SegmentMode::Dual }.validate()
            }
            GuidanceSchedule::Cadence { every } => {
                if *every == 0 {
                    return Err(Error::Config(
                        "cadence must be >= 1 (1 = guide every step)".into(),
                    ));
                }
                Ok(())
            }
        }
    }

    /// Per-step optimized mask for an `n`-step loop: `true` = the step
    /// belongs to the optimized set (single-pass per the strategy).
    pub fn optimized_mask(&self, n: usize) -> Vec<bool> {
        match self {
            GuidanceSchedule::Window(w) => (0..n).map(|i| w.contains(i, n)).collect(),
            GuidanceSchedule::Segments(segs) => {
                let mut mask = vec![false; n];
                for s in segs {
                    let (lo, hi) = s.idx_range(n);
                    for m in mask[lo..hi].iter_mut() {
                        *m = s.mode == SegmentMode::Optimized;
                    }
                }
                mask
            }
            GuidanceSchedule::Interval { lo, hi } => {
                let seg = Segment { lo: *lo, hi: *hi, mode: SegmentMode::Dual };
                let (lo, hi) = seg.idx_range(n);
                (0..n).map(|i| !(lo..hi).contains(&i)).collect()
            }
            GuidanceSchedule::Cadence { every } => (0..n).map(|i| i % every != 0).collect(),
        }
    }

    /// Optimized steps for an `n`-step loop.
    pub fn optimized_count(&self, n: usize) -> usize {
        self.optimized_mask(n).iter().filter(|&&m| m).count()
    }

    /// May the QoS actuator replace this schedule with a wider
    /// `Last`-placed window? Only the default (no window) and explicit
    /// `Last` windows are movable — every other schedule is a deliberate
    /// experiment the policy must not silently rewrite.
    pub fn widenable(&self) -> bool {
        match self {
            GuidanceSchedule::Window(w) => {
                w.fraction == 0.0 || matches!(w.position, WindowPosition::Last)
            }
            _ => false,
        }
    }

    /// The `Last`-window fraction when this schedule is one (for stats).
    pub fn last_fraction(&self) -> f64 {
        match self {
            GuidanceSchedule::Window(w) if matches!(w.position, WindowPosition::Last) => {
                w.fraction
            }
            _ => 0.0,
        }
    }

    /// Build a schedule from the four optional surface fields — the one
    /// constructor the TOML, CLI and wire surfaces all share, so the
    /// mutual-exclusion rule and the per-kind dispatch cannot drift
    /// between them. `Ok(None)` means no schedule was configured (keep
    /// the surface's default).
    pub fn from_parts(
        window: Option<(f64, WindowPosition)>,
        segments: Option<&str>,
        interval: Option<&str>,
        cadence: Option<usize>,
    ) -> Result<Option<GuidanceSchedule>> {
        let picked = [
            window.is_some(),
            segments.is_some(),
            interval.is_some(),
            cadence.is_some(),
        ]
        .iter()
        .filter(|&&p| p)
        .count();
        if picked > 1 {
            return Err(Error::Config(
                "window, segments, interval and cadence are mutually exclusive — \
                 configure exactly one schedule"
                    .into(),
            ));
        }
        let sched = if let Some((fraction, position)) = window {
            GuidanceSchedule::Window(WindowSpec { fraction, position })
        } else if let Some(s) = segments {
            Self::parse_segments(s)?
        } else if let Some(s) = interval {
            Self::parse_interval(s)?
        } else if let Some(every) = cadence {
            GuidanceSchedule::Cadence { every }
        } else {
            return Ok(None);
        };
        sched.validate()?;
        Ok(Some(sched))
    }

    /// Parse `"lo-hi"` as a guided interval (e.g. `"0.25-0.75"`).
    pub fn parse_interval(s: &str) -> Result<GuidanceSchedule> {
        let (lo, hi) = s
            .split_once('-')
            .ok_or_else(|| Error::Config(format!("interval {s:?} must be \"lo-hi\"")))?;
        let lo: f64 = lo
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("interval {s:?}: bad lower bound")))?;
        let hi: f64 = hi
            .trim()
            .parse()
            .map_err(|_| Error::Config(format!("interval {s:?}: bad upper bound")))?;
        let sched = GuidanceSchedule::Interval { lo, hi };
        sched.validate()?;
        Ok(sched)
    }

    /// Parse a comma-separated segment list: each item is `"lo-hi"`
    /// (optimized) or `"!lo-hi"` (forced dual), applied in order, e.g.
    /// `"0.0-0.2,0.8-1.0"` or `"0.0-1.0,!0.4-0.6"`.
    pub fn parse_segments(s: &str) -> Result<GuidanceSchedule> {
        let mut segs = Vec::new();
        for item in s.split(',') {
            let item = item.trim();
            if item.is_empty() {
                return Err(Error::Config(format!("segments {s:?}: empty item")));
            }
            let (mode, body) = match item.strip_prefix('!') {
                Some(rest) => (SegmentMode::Dual, rest),
                None => (SegmentMode::Optimized, item),
            };
            let GuidanceSchedule::Interval { lo, hi } = Self::parse_interval(body)? else {
                unreachable!()
            };
            segs.push(Segment { lo, hi, mode });
        }
        if segs.is_empty() {
            return Err(Error::Config("segments list is empty".into()));
        }
        let sched = GuidanceSchedule::Segments(segs);
        sched.validate()?;
        Ok(sched)
    }

    /// Human-readable label for bench tables and logs.
    pub fn label(&self) -> String {
        match self {
            GuidanceSchedule::Window(w) => w.label(),
            GuidanceSchedule::Segments(segs) => {
                let items: Vec<String> = segs
                    .iter()
                    .map(|s| {
                        let bang = if s.mode == SegmentMode::Dual { "!" } else { "" };
                        format!("{bang}{}-{}", s.lo, s.hi)
                    })
                    .collect();
                format!("segments {}", items.join(","))
            }
            GuidanceSchedule::Interval { lo, hi } => format!("interval {lo}-{hi}"),
            GuidanceSchedule::Cadence { every } => format!("cadence /{every}"),
        }
    }
}

/// One denoising step's compiled decision.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepPlan {
    /// What the engine executes at this step (carries scale + reuse
    /// kind where applicable).
    pub mode: GuidanceMode,
}

impl StepPlan {
    /// UNet-slot cost of this step (2 for dual, 1 otherwise).
    pub fn cost(&self) -> usize {
        self.mode.unet_evals()
    }
}

/// The compiled per-step guidance decisions of one trajectory.
#[derive(Debug, Clone, PartialEq)]
pub struct GuidancePlan {
    steps: Vec<StepPlan>,
}

impl GuidancePlan {
    /// Compile a schedule into a plan for an `n`-step loop.
    ///
    /// The walk owns the reuse semantics previously buried in the
    /// policy/strategy pair, generalized to arbitrary optimized sets:
    /// a reuse step with no prior dual anchor is forced Dual (cold
    /// cache), and after `refresh_every` consecutive reuse steps one
    /// true dual step re-anchors the cache. Any dual step — scheduled
    /// or forced — resets the cadence. `scale == 1` collapses Eq. 1 to
    /// the conditional term, so the whole plan is `Unguided`.
    pub fn compile(
        schedule: &GuidanceSchedule,
        scale: f32,
        strategy: GuidanceStrategy,
        n: usize,
    ) -> Result<GuidancePlan> {
        Self::compile_walk(schedule, scale, strategy, n, false)
    }

    /// Compile for an engine with a *shared* uncond cache attached
    /// (DESIGN.md §13): the anchor can come from a different in-flight
    /// sample, so a reuse step before any local dual pass is planned
    /// as `Reuse` instead of being forced Dual. The refresh cadence is
    /// kept — it bounds staleness regardless of where anchors come
    /// from. The engine fails the sample with a typed `Error::Engine`
    /// if, at execution time, neither the shared tier nor the local
    /// cache can supply the anchor.
    pub fn compile_shared(
        schedule: &GuidanceSchedule,
        scale: f32,
        strategy: GuidanceStrategy,
        n: usize,
    ) -> Result<GuidancePlan> {
        Self::compile_walk(schedule, scale, strategy, n, true)
    }

    fn compile_walk(
        schedule: &GuidanceSchedule,
        scale: f32,
        strategy: GuidanceStrategy,
        n: usize,
        anchor_free: bool,
    ) -> Result<GuidancePlan> {
        schedule.validate()?;
        if !scale.is_finite() || scale < 0.0 {
            return Err(Error::Config(format!(
                "guidance scale {scale} must be finite and >= 0"
            )));
        }
        if (scale - 1.0).abs() < 1e-6 {
            return Ok(GuidancePlan {
                steps: vec![StepPlan { mode: GuidanceMode::Unguided }; n],
            });
        }
        let mask = schedule.optimized_mask(n);
        let mut steps = Vec::with_capacity(n);
        let mut have_anchor = anchor_free;
        let mut consecutive = 0usize;
        for &optimized in &mask {
            let mode = if !optimized {
                have_anchor = true;
                consecutive = 0;
                GuidanceMode::Dual { scale }
            } else {
                match strategy {
                    GuidanceStrategy::CondOnly => GuidanceMode::CondOnly,
                    GuidanceStrategy::Reuse { kind, refresh_every } => {
                        if !have_anchor || (refresh_every > 0 && consecutive == refresh_every) {
                            have_anchor = true;
                            consecutive = 0;
                            GuidanceMode::Dual { scale }
                        } else {
                            consecutive += 1;
                            GuidanceMode::Reuse { scale, kind }
                        }
                    }
                }
            };
            steps.push(StepPlan { mode });
        }
        Ok(GuidancePlan { steps })
    }

    /// The conservative all-dual plan used as the *online overlay* for
    /// adaptive requests: the controller's decisions cannot be peeked,
    /// so admission reserves dual cost for every remaining step, and
    /// [`GuidancePlan::record_executed`] rewrites each step with what
    /// actually ran — keeping the executed plan auditable against the
    /// same `total_unet_evals` invariant as static plans.
    pub fn conservative_dual(scale: f32, n: usize) -> GuidancePlan {
        GuidancePlan {
            steps: vec![StepPlan { mode: GuidanceMode::Dual { scale } }; n],
        }
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// The compiled per-step entries.
    pub fn steps(&self) -> &[StepPlan] {
        &self.steps
    }

    /// Mode of step `i`.
    pub fn mode(&self, i: usize) -> GuidanceMode {
        self.steps[i].mode
    }

    /// Overwrite step `i` with the mode that actually executed (the
    /// adaptive controller's online overlay).
    pub fn record_executed(&mut self, i: usize, mode: GuidanceMode) {
        self.steps[i] = StepPlan { mode };
    }

    /// Total UNet evaluations of the whole plan — the single invariant
    /// every layer audits executed work against.
    pub fn total_unet_evals(&self) -> usize {
        self.steps.iter().map(|s| s.cost()).sum()
    }

    /// UNet-slot cost of step `i` (0 past the end).
    pub fn next_cost(&self, i: usize) -> usize {
        self.steps.get(i).map(|s| s.cost()).unwrap_or(0)
    }

    /// Summed UNet-slot cost of steps `from..` — the trajectory's
    /// remaining work.
    pub fn remaining_cost(&self, from: usize) -> usize {
        self.steps.iter().skip(from).map(|s| s.cost()).sum()
    }

    /// Largest per-step cost any step `from..` can incur — the
    /// continuous batcher's admission currency: a cohort whose peak
    /// costs sum within the slot budget can never overshoot it.
    pub fn peak_remaining_cost(&self, from: usize) -> usize {
        self.steps.iter().skip(from).map(|s| s.cost()).max().unwrap_or(0)
    }

    /// Measured milliseconds of step `i` under a calibrated table
    /// (0.0 past the end) — the priced sibling of [`Self::next_cost`].
    pub fn next_cost_ms(&self, i: usize, table: &super::CostTable) -> f64 {
        self.steps
            .get(i)
            .map(|s| table.sample_step_ms(super::StepMode::of(&s.mode)))
            .unwrap_or(0.0)
    }

    /// Measured milliseconds of the whole plan — the priced sibling of
    /// [`Self::total_unet_evals`]. Under a proportional table this is
    /// exactly `total_unet_evals × unit_ms` (pricing is a relabeling).
    pub fn cost_ms(&self, table: &super::CostTable) -> f64 {
        self.remaining_cost_ms(0, table)
    }

    /// Measured milliseconds of steps `from..` — the priced sibling of
    /// [`Self::remaining_cost`].
    pub fn remaining_cost_ms(&self, from: usize, table: &super::CostTable) -> f64 {
        self.steps
            .iter()
            .skip(from)
            .map(|s| table.sample_step_ms(super::StepMode::of(&s.mode)))
            .sum()
    }

    /// Largest per-step milliseconds any step `from..` can incur — the
    /// priced admission currency of the continuous batcher's `budget_ms`
    /// mode (sibling of [`Self::peak_remaining_cost`]).
    pub fn peak_remaining_cost_ms(&self, from: usize, table: &super::CostTable) -> f64 {
        self.steps
            .iter()
            .skip(from)
            .map(|s| table.sample_step_ms(super::StepMode::of(&s.mode)))
            .fold(0.0, f64::max)
    }

    /// Steps that run a single UNet pass.
    pub fn single_pass_steps(&self) -> usize {
        self.steps.iter().filter(|s| s.cost() == 1).count()
    }

    /// Fraction of the loop that runs single-pass — the plan-derived
    /// *effective shed* the QoS feedback loop keys on (refresh and
    /// cold-cache steps pay dual cost, so this is what the analytic
    /// `GuidanceStrategy::effective_fraction` only approximates).
    pub fn effective_fraction(&self) -> f64 {
        if self.steps.is_empty() {
            return 0.0;
        }
        self.single_pass_steps() as f64 / self.steps.len() as f64
    }

    /// Does any step run guidance reuse (the engine's cue to record the
    /// uncond-eps cache on dual steps)?
    pub fn has_reuse(&self) -> bool {
        self.steps
            .iter()
            .any(|s| matches!(s.mode, GuidanceMode::Reuse { .. }))
    }

    /// Compact run-length summary of the mode sequence, e.g.
    /// `"12D 4R 1D 7C"` (D dual, C cond-only, R reuse, U unguided) —
    /// echoed on the wire so clients can audit the executed plan.
    pub fn summary(&self) -> String {
        let letter = |m: &GuidanceMode| match m {
            GuidanceMode::Dual { .. } => 'D',
            GuidanceMode::CondOnly => 'C',
            GuidanceMode::Reuse { .. } => 'R',
            GuidanceMode::Unguided => 'U',
        };
        let mut out = String::new();
        let mut run: Option<(char, usize)> = None;
        for s in &self.steps {
            let c = letter(&s.mode);
            match run {
                Some((rc, count)) if rc == c => run = Some((rc, count + 1)),
                Some((rc, count)) => {
                    out.push_str(&format!("{count}{rc} "));
                    run = Some((c, 1));
                }
                None => run = Some((c, 1)),
            }
        }
        if let Some((rc, count)) = run {
            out.push_str(&format!("{count}{rc}"));
        }
        if out.is_empty() {
            "empty".into()
        } else {
            out
        }
    }

    /// [`Self::summary`] plus the plan's measured price, e.g.
    /// `"40D 10C ≈ 812ms"` — what operator surfaces print once a cost
    /// table is attached.
    pub fn priced_summary(&self, table: &super::CostTable) -> String {
        format!("{} ≈ {:.0}ms", self.summary(), self.cost_ms(table))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::guidance::ReuseKind;
    use crate::testutil::prop::forall;

    fn dual(m: GuidanceMode) -> bool {
        matches!(m, GuidanceMode::Dual { .. })
    }

    /// The legacy per-step walk (window + strategy.in_window_mode) the
    /// plan compiler must reproduce exactly for `Window` schedules.
    fn legacy_decide(
        w: &WindowSpec,
        strategy: GuidanceStrategy,
        scale: f32,
        i: usize,
        n: usize,
    ) -> GuidanceMode {
        if (scale - 1.0).abs() < 1e-6 {
            return GuidanceMode::Unguided;
        }
        if w.contains(i, n) {
            let (start, _) = w.range(n);
            strategy.in_window_mode(i - start, start, scale)
        } else {
            GuidanceMode::Dual { scale }
        }
    }

    #[test]
    fn window_plans_match_legacy_walk() {
        forall("plan == legacy window walk", 300, |g| {
            let n = g.usize_in(1, 120);
            let f = g.f64_in(0.0, 1.0);
            let w = match g.usize_in(0, 3) {
                0 => WindowSpec::last(f),
                1 => WindowSpec::first(f),
                2 => WindowSpec::middle(f),
                _ => WindowSpec::at_offset(g.f64_in(0.0, 1.0), f),
            };
            let strategy = match g.usize_in(0, 2) {
                0 => GuidanceStrategy::CondOnly,
                1 => GuidanceStrategy::Reuse {
                    kind: ReuseKind::Hold,
                    refresh_every: g.usize_in(0, 6),
                },
                _ => GuidanceStrategy::Reuse {
                    kind: ReuseKind::Extrapolate,
                    refresh_every: g.usize_in(0, 6),
                },
            };
            let scale = if g.bool() { g.f32_in(1.5, 12.0) } else { 1.0 };
            let plan =
                GuidancePlan::compile(&GuidanceSchedule::Window(w), scale, strategy, n).unwrap();
            assert_eq!(plan.len(), n);
            for i in 0..n {
                assert_eq!(
                    plan.mode(i),
                    legacy_decide(&w, strategy, scale, i, n),
                    "step {i}/{n} of {w:?} {strategy:?}"
                );
            }
        });
    }

    #[test]
    fn compile_shared_lifts_cold_cache_anchor() {
        // a full-window reuse plan: local compile must force step 0
        // Dual (cold cache); the shared compile may plan it Reuse
        // because the anchor can come from another in-flight sample
        let schedule = GuidanceSchedule::Window(WindowSpec::last(1.0));
        let strategy = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 };
        let local = GuidancePlan::compile(&schedule, 7.5, strategy, 8).unwrap();
        assert!(dual(local.mode(0)));
        let shared = GuidancePlan::compile_shared(&schedule, 7.5, strategy, 8).unwrap();
        for i in 0..8 {
            assert!(matches!(shared.mode(i), GuidanceMode::Reuse { .. }), "step {i}");
        }
        // the refresh cadence still bounds staleness under sharing
        let strategy = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 };
        let shared = GuidancePlan::compile_shared(&schedule, 7.5, strategy, 8).unwrap();
        assert!(dual(shared.mode(2)), "{}", shared.summary());
        // non-reuse strategies compile identically either way
        let a = GuidancePlan::compile(&schedule, 7.5, GuidanceStrategy::CondOnly, 8).unwrap();
        let b =
            GuidancePlan::compile_shared(&schedule, 7.5, GuidanceStrategy::CondOnly, 8).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn compile_is_deterministic() {
        let sched = GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 };
        let s = GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 3 };
        let a = GuidancePlan::compile(&sched, 7.5, s, 50).unwrap();
        let b = GuidancePlan::compile(&sched, 7.5, s, 50).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn cadence_guides_every_kth_step() {
        let plan = GuidancePlan::compile(
            &GuidanceSchedule::Cadence { every: 4 },
            7.5,
            GuidanceStrategy::CondOnly,
            10,
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(dual(plan.mode(i)), i % 4 == 0, "step {i}");
        }
        // 3 dual (0, 4, 8) + 7 single
        assert_eq!(plan.total_unet_evals(), 13);
        // cadence 1 == full CFG
        let full = GuidancePlan::compile(
            &GuidanceSchedule::Cadence { every: 1 },
            7.5,
            GuidanceStrategy::CondOnly,
            10,
        )
        .unwrap();
        assert_eq!(full.total_unet_evals(), 20);
    }

    #[test]
    fn interval_guides_only_inside() {
        // guided [2, 8) of 10 steps, optimized outside
        let plan = GuidancePlan::compile(
            &GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 },
            7.5,
            GuidanceStrategy::CondOnly,
            10,
        )
        .unwrap();
        for i in 0..10 {
            assert_eq!(dual(plan.mode(i)), (2..8).contains(&i), "step {i}");
        }
        assert_eq!(plan.total_unet_evals(), 16);
        // with reuse, the leading optimized run opens with a cold-cache
        // dual anchor at step 0
        let reuse = GuidancePlan::compile(
            &GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 },
            7.5,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 },
            10,
        )
        .unwrap();
        assert!(dual(reuse.mode(0)), "cold cache must anchor");
        assert!(matches!(reuse.mode(1), GuidanceMode::Reuse { .. }));
        assert_eq!(reuse.total_unet_evals(), 17);
    }

    #[test]
    fn segments_apply_in_order() {
        // optimize everything, then carve a forced-dual middle back out
        let sched = GuidanceSchedule::Segments(vec![
            Segment::optimized(0.0, 1.0),
            Segment::dual(0.4, 0.6),
        ]);
        let plan =
            GuidancePlan::compile(&sched, 7.5, GuidanceStrategy::CondOnly, 10).unwrap();
        for i in 0..10 {
            assert_eq!(dual(plan.mode(i)), (4..6).contains(&i), "step {i}");
        }
        // disjoint optimized segments leave the gap dual
        let sched = GuidanceSchedule::Segments(vec![
            Segment::optimized(0.0, 0.2),
            Segment::optimized(0.8, 1.0),
        ]);
        let plan =
            GuidancePlan::compile(&sched, 7.5, GuidanceStrategy::CondOnly, 10).unwrap();
        let optimized: Vec<usize> = (0..10).filter(|&i| !dual(plan.mode(i))).collect();
        assert_eq!(optimized, vec![0, 1, 8, 9]);
    }

    #[test]
    fn reuse_reanchors_after_any_dual() {
        // optimized [0,4) + [6,10): the dual gap re-anchors the cache,
        // so the second run needs no cold-start dual
        let sched = GuidanceSchedule::Segments(vec![
            Segment::optimized(0.0, 0.4),
            Segment::optimized(0.6, 1.0),
        ]);
        let plan = GuidancePlan::compile(
            &sched,
            7.5,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 0 },
            10,
        )
        .unwrap();
        assert!(dual(plan.mode(0)), "first run cold-starts");
        assert!(matches!(plan.mode(1), GuidanceMode::Reuse { .. }));
        assert!(dual(plan.mode(4)) && dual(plan.mode(5)), "gap is dual");
        assert!(
            matches!(plan.mode(6), GuidanceMode::Reuse { .. }),
            "gap re-anchored the cache"
        );
    }

    #[test]
    fn unguided_scale_collapses_everything() {
        for sched in [
            GuidanceSchedule::none(),
            GuidanceSchedule::Cadence { every: 3 },
            GuidanceSchedule::Interval { lo: 0.1, hi: 0.9 },
        ] {
            let plan =
                GuidancePlan::compile(&sched, 1.0, GuidanceStrategy::CondOnly, 8).unwrap();
            assert!(plan.steps().iter().all(|s| s.mode == GuidanceMode::Unguided));
            assert_eq!(plan.total_unet_evals(), 8);
        }
    }

    #[test]
    fn cost_queries() {
        // 4 dual + 4 cond-only
        let plan = GuidancePlan::compile(
            &GuidanceSchedule::Window(WindowSpec::last(0.5)),
            7.5,
            GuidanceStrategy::CondOnly,
            8,
        )
        .unwrap();
        assert_eq!(plan.total_unet_evals(), 12);
        assert_eq!(plan.remaining_cost(0), 12);
        assert_eq!(plan.remaining_cost(4), 4);
        assert_eq!(plan.peak_remaining_cost(0), 2);
        assert_eq!(plan.peak_remaining_cost(4), 1);
        assert_eq!(plan.peak_remaining_cost(8), 0);
        assert_eq!(plan.next_cost(0), 2);
        assert_eq!(plan.next_cost(7), 1);
        assert_eq!(plan.next_cost(8), 0);
        assert_eq!(plan.single_pass_steps(), 4);
        assert!((plan.effective_fraction() - 0.5).abs() < 1e-12);
        assert!(!plan.has_reuse());
    }

    #[test]
    fn record_executed_overlays() {
        let mut plan = GuidancePlan::conservative_dual(7.5, 4);
        assert_eq!(plan.total_unet_evals(), 8);
        assert_eq!(plan.peak_remaining_cost(0), 2);
        plan.record_executed(2, GuidanceMode::CondOnly);
        plan.record_executed(3, GuidanceMode::CondOnly);
        assert_eq!(plan.total_unet_evals(), 6);
        assert_eq!(plan.summary(), "2D 2C");
    }

    #[test]
    fn summary_run_lengths() {
        let plan = GuidancePlan::compile(
            &GuidanceSchedule::Window(WindowSpec::last(0.5)),
            7.5,
            GuidanceStrategy::Reuse { kind: ReuseKind::Hold, refresh_every: 2 },
            10,
        )
        .unwrap();
        // 5 dual, then R R D R R
        assert_eq!(plan.summary(), "5D 2R 1D 2R");
        let empty = GuidancePlan::compile(
            &GuidanceSchedule::none(),
            7.5,
            GuidanceStrategy::CondOnly,
            0,
        )
        .unwrap();
        assert_eq!(empty.summary(), "empty");
        assert!(empty.is_empty());
    }

    #[test]
    fn schedule_validation() {
        assert!(GuidanceSchedule::Cadence { every: 0 }.validate().is_err());
        assert!(GuidanceSchedule::Cadence { every: 1 }.validate().is_ok());
        assert!(GuidanceSchedule::Interval { lo: 0.5, hi: 0.2 }.validate().is_err());
        assert!(GuidanceSchedule::Interval { lo: -0.1, hi: 0.5 }.validate().is_err());
        assert!(GuidanceSchedule::Interval { lo: 0.0, hi: 1.5 }.validate().is_err());
        assert!(GuidanceSchedule::Interval { lo: f64::NAN, hi: 0.5 }.validate().is_err());
        assert!(GuidanceSchedule::Segments(vec![Segment::optimized(0.3, 0.1)])
            .validate()
            .is_err());
        assert!(GuidanceSchedule::Window(WindowSpec::last(2.0)).validate().is_err());
        assert!(GuidancePlan::compile(
            &GuidanceSchedule::none(),
            f32::NAN,
            GuidanceStrategy::CondOnly,
            10
        )
        .is_err());
    }

    #[test]
    fn parse_interval_and_segments() {
        assert_eq!(
            GuidanceSchedule::parse_interval("0.25-0.75").unwrap(),
            GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 }
        );
        assert!(GuidanceSchedule::parse_interval("0.75-0.25").is_err());
        assert!(GuidanceSchedule::parse_interval("0.25").is_err());
        assert!(GuidanceSchedule::parse_interval("a-b").is_err());
        assert_eq!(
            GuidanceSchedule::parse_segments("0.0-0.2, 0.8-1.0").unwrap(),
            GuidanceSchedule::Segments(vec![
                Segment::optimized(0.0, 0.2),
                Segment::optimized(0.8, 1.0),
            ])
        );
        assert_eq!(
            GuidanceSchedule::parse_segments("0.0-1.0,!0.4-0.6").unwrap(),
            GuidanceSchedule::Segments(vec![
                Segment::optimized(0.0, 1.0),
                Segment::dual(0.4, 0.6),
            ])
        );
        assert!(GuidanceSchedule::parse_segments("").is_err());
        assert!(GuidanceSchedule::parse_segments("0.0-0.2,,0.8-1.0").is_err());
    }

    #[test]
    fn from_parts_shared_constructor() {
        // nothing configured -> None (surface keeps its default)
        assert_eq!(GuidanceSchedule::from_parts(None, None, None, None).unwrap(), None);
        assert_eq!(
            GuidanceSchedule::from_parts(Some((0.2, WindowPosition::Last)), None, None, None)
                .unwrap(),
            Some(GuidanceSchedule::Window(WindowSpec::last(0.2)))
        );
        assert_eq!(
            GuidanceSchedule::from_parts(None, None, Some("0.25-0.75"), None).unwrap(),
            Some(GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 })
        );
        assert_eq!(
            GuidanceSchedule::from_parts(None, None, None, Some(4)).unwrap(),
            Some(GuidanceSchedule::Cadence { every: 4 })
        );
        assert_eq!(
            GuidanceSchedule::from_parts(None, Some("0.0-0.2"), None, None).unwrap(),
            Some(GuidanceSchedule::Segments(vec![Segment::optimized(0.0, 0.2)]))
        );
        // mutual exclusion, validation
        assert!(GuidanceSchedule::from_parts(None, None, Some("0.2-0.8"), Some(4)).is_err());
        assert!(GuidanceSchedule::from_parts(
            Some((0.2, WindowPosition::Last)),
            None,
            None,
            Some(4)
        )
        .is_err());
        assert!(GuidanceSchedule::from_parts(None, None, None, Some(0)).is_err());
        assert!(GuidanceSchedule::from_parts(Some((1.5, WindowPosition::Last)), None, None, None)
            .is_err());
    }

    #[test]
    fn widenable_and_labels() {
        assert!(GuidanceSchedule::none().widenable());
        assert!(GuidanceSchedule::Window(WindowSpec::last(0.3)).widenable());
        assert!(!GuidanceSchedule::Window(WindowSpec::first(0.3)).widenable());
        assert!(!GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 }.widenable());
        assert!(!GuidanceSchedule::Cadence { every: 4 }.widenable());
        assert_eq!(GuidanceSchedule::none().label(), "no opt.");
        assert_eq!(GuidanceSchedule::Cadence { every: 4 }.label(), "cadence /4");
        assert_eq!(
            GuidanceSchedule::Interval { lo: 0.25, hi: 0.75 }.label(),
            "interval 0.25-0.75"
        );
        assert_eq!(GuidanceSchedule::Window(WindowSpec::last(0.3)).last_fraction(), 0.3);
        assert_eq!(GuidanceSchedule::Cadence { every: 4 }.last_fraction(), 0.0);
    }

    #[test]
    fn mask_counts_consistent() {
        forall("schedule mask consistency", 200, |g| {
            let n = g.usize_in(1, 150);
            let sched = match g.usize_in(0, 3) {
                0 => GuidanceSchedule::Window(WindowSpec::last(g.f64_in(0.0, 1.0))),
                1 => {
                    let lo = g.f64_in(0.0, 1.0);
                    GuidanceSchedule::Interval { lo, hi: g.f64_in(lo, 1.0) }
                }
                2 => GuidanceSchedule::Cadence { every: g.usize_in(1, 10) },
                _ => {
                    let lo = g.f64_in(0.0, 1.0);
                    GuidanceSchedule::Segments(vec![Segment::optimized(lo, g.f64_in(lo, 1.0))])
                }
            };
            sched.validate().unwrap();
            let mask = sched.optimized_mask(n);
            assert_eq!(mask.len(), n);
            assert_eq!(sched.optimized_count(n), mask.iter().filter(|&&m| m).count());
            // plan cost bracket: n <= evals <= 2n for any strategy
            let plan =
                GuidancePlan::compile(&sched, 7.5, GuidanceStrategy::CondOnly, n).unwrap();
            let evals = plan.total_unet_evals();
            assert!(evals >= n && evals <= 2 * n, "{evals} outside [{n}, {}]", 2 * n);
            assert_eq!(evals, 2 * n - sched.optimized_count(n));
        });
    }

    #[test]
    fn priced_views_relabel_unit_costs_under_proportional_table() {
        use crate::guidance::CostTable;
        forall("priced plan == unit plan × unit_ms", 100, |g| {
            let n = g.usize_in(1, 120);
            // dyadic units keep every partial sum exact in f64, so the
            // relabeling claim can be asserted with == rather than ≈
            let unit_ms = [0.25, 0.5, 1.0, 2.0, 4.0][g.usize_in(0, 4)];
            let table = CostTable::proportional(unit_ms, &[1, 2, 4]);
            let sched = GuidanceSchedule::Window(WindowSpec::last(g.f64_in(0.0, 1.0)));
            let plan =
                GuidancePlan::compile(&sched, 7.5, GuidanceStrategy::CondOnly, n).unwrap();
            assert_eq!(plan.cost_ms(&table), plan.total_unet_evals() as f64 * unit_ms);
            let from = g.usize_in(0, n);
            assert_eq!(
                plan.remaining_cost_ms(from, &table),
                plan.remaining_cost(from) as f64 * unit_ms
            );
            assert_eq!(
                plan.peak_remaining_cost_ms(from, &table),
                plan.peak_remaining_cost(from) as f64 * unit_ms
            );
            assert_eq!(plan.next_cost_ms(from, &table), plan.next_cost(from) as f64 * unit_ms);
            assert_eq!(table.fallback_count(), 0, "proportional grid fully covers");
        });
    }

    #[test]
    fn priced_summary_appends_the_price() {
        let table = crate::guidance::CostTable::proportional(10.0, &[1]);
        let sched = GuidanceSchedule::Window(WindowSpec::last(0.2));
        let plan = GuidancePlan::compile(&sched, 7.5, GuidanceStrategy::CondOnly, 50).unwrap();
        // 40 dual (800ms) + 10 cond-only (100ms)
        assert_eq!(plan.priced_summary(&table), "40D 10C ≈ 900ms");
    }
}
