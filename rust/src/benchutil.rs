//! Bench harness utilities (offline substitute for `criterion`).
//!
//! Each `[[bench]]` target is a plain `harness = false` binary that uses
//! [`BenchRunner`] for warmup + timed samples and prints aligned tables
//! matching the paper's rows. Results are also dumped as JSON next to the
//! binary output so EXPERIMENTS.md numbers are machine-checkable.

use std::time::Instant;

use crate::json::Value;
use crate::metrics::SampleStats;

/// Warmup-then-measure runner.
pub struct BenchRunner {
    pub warmup_iters: usize,
    pub sample_iters: usize,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner { warmup_iters: 3, sample_iters: 10 }
    }
}

impl BenchRunner {
    pub fn new(warmup_iters: usize, sample_iters: usize) -> Self {
        BenchRunner { warmup_iters, sample_iters }
    }

    /// Time `f` (seconds per call) after warmup; returns per-call stats.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> SampleStats {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.sample_iters);
        for _ in 0..self.sample_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        SampleStats::from(&samples)
    }
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write a bench result JSON next to the repo root (bench_results/).
pub fn write_result_json(bench_name: &str, value: &Value) {
    let dir = std::path::Path::new("bench_results");
    if std::fs::create_dir_all(dir).is_err() {
        return; // benches must not fail on result-dump problems
    }
    let path = dir.join(format!("{bench_name}.json"));
    let _ = std::fs::write(&path, value.to_string());
    eprintln!("[bench] wrote {}", path.display());
}

/// Parse `--fast` style flags shared by all bench binaries.
pub struct BenchArgs {
    /// Reduced sample counts for CI smoke runs.
    pub fast: bool,
    /// Artifact directory override.
    pub artifacts: String,
}

impl BenchArgs {
    pub fn parse() -> Self {
        let mut fast = false;
        let mut artifacts = default_artifacts_dir();
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            match a.as_str() {
                "--fast" => fast = true,
                "--artifacts" => {
                    artifacts = args.next().unwrap_or(artifacts);
                }
                // `cargo bench` passes --bench; ignore unknown flags so the
                // harness stays robust under test runners
                _ => {}
            }
        }
        BenchArgs { fast, artifacts }
    }
}

/// Resolve the artifacts dir from the env or the standard layout.
pub fn default_artifacts_dir() -> String {
    if let Ok(dir) = std::env::var("SG_ARTIFACTS") {
        return dir;
    }
    "artifacts/tiny".to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_collects_samples() {
        let r = BenchRunner::new(1, 5);
        let mut calls = 0;
        let stats = r.run(|| {
            calls += 1;
            std::thread::sleep(std::time::Duration::from_micros(100));
        });
        assert_eq!(calls, 6); // 1 warmup + 5 samples
        assert_eq!(stats.n, 5);
        assert!(stats.mean >= 50e-6);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Iterations optimized", "Time(s)", "Saving"]);
        t.row(&["No opt.".into(), "9.94".into(), "-".into()]);
        t.row(&["20% of iters".into(), "9.13".into(), "8.2%".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{s}");
        assert!(s.contains("8.2%"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
