//! Replica cluster: plan-cost-aware routing over heterogeneous engine
//! replicas (DESIGN.md §11).
//!
//! One process, N **replicas** — each an independent [`Coordinator`]
//! (its own batch mode, slot budget and worker pool, modeling mixed
//! hardware) over a shared [`Engine`]. The cluster owns what used to be
//! per-coordinator concerns:
//!
//! ```text
//!   clients ─> submit ─> [cluster QoS: aggregate depth] ─> [Router]
//!                            │ 429/503                       │ plan-cost
//!                            ▼                               ▼ placement
//!                          shed                      ┌─ replica 0 (continuous, budget 8)
//!                                                    ├─ replica 1 (continuous, budget 4)
//!                                relay threads <──── └─ replica 2 (fixed, batch 4)
//!                                  │  completions: cluster latency histogram
//!                                  └─ failures/sheds: requeue onto survivors
//! ```
//!
//! * **Admission** is cluster-level: the [`QosPolicy`] sees the
//!   *aggregate* outstanding depth across every replica (and, via the
//!   shared policy installed in each replica coordinator, the merged
//!   slot-occupancy / service-time feedback from all workers). The
//!   actuator stays what it has been since DESIGN.md §10 — a per-request
//!   plan rewriter. Replicas execute pre-admitted work
//!   ([`Coordinator::submit_preadmitted`]), so nothing is admitted twice.
//! * **Routing** is plan-cost-aware ([`RoutePolicy::PlanCost`]): each
//!   admitted request is weighed by its compiled plan's
//!   `total_unet_evals()` — a 50%-optimized schedule counts as half the
//!   load of a full-CFG request — and placed by weighted
//!   least-outstanding-evals with power-of-two-choices. Round-robin is
//!   kept as the measurable baseline (`--route round-robin`). With
//!   calibrated [`CostTable`]s installed ([`ClusterConfig::cost_tables`],
//!   DESIGN.md §15) the same router runs in **measured milliseconds**:
//!   jobs are priced by [`GuidancePlan::cost_ms`](crate::guidance::GuidancePlan::cost_ms)
//!   against the fleet-reference table (stored as integer microseconds)
//!   and each replica's weight is scaled by its measured per-slot speed,
//!   so a replica whose dual step is twice as fast absorbs twice the
//!   outstanding milliseconds. A single shared table scales every weight
//!   and every job by the same constants — placements are preserved
//!   bit-exactly versus unit-slot routing.
//! * **Lifecycle**: [`ReplicaSet::kill`] ejects a replica — the router
//!   stops placing on it, its executing cohort drains, and its queued
//!   jobs come back as explicit 503 sheds which the relay **requeues**
//!   onto surviving replicas (each job carries an excluded-replica list
//!   so a poison job cannot ping-pong forever). Graceful
//!   [`ReplicaSet::shutdown`] resolves every outstanding ticket.
//!
//! `tests/cluster_equivalence.rs` holds the core invariants: a 1-replica
//! cluster is bit-identical to the plain coordinator, placements are
//! deterministic (same trace + seed + policy), and a mid-trace kill
//! loses no requests. `benches/cluster_scaling.rs` enforces the headline
//! scaling and routing wins in virtual time.

mod router;

pub use router::{RoutePolicy, Router};

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cache::{canonical_key, CacheConfig};
use crate::config::{ServerConfig, TomlDoc};
use crate::coordinator::{
    BatchMode, CancelHandle, Coordinator, CoordinatorConfig, CoordinatorStats, Submit, Ticket,
    WatchOptions, WatchSink, Watched,
};
use crate::engine::{Engine, GenerationOutput, GenerationRequest};
use crate::error::{Error, Result};
use crate::guidance::{CostTable, PlanSearch, StepMode};
use crate::metrics::LatencyHistogram;
use crate::qos::{AdmissionDecision, QosMeta, QosPolicy};
use crate::telemetry::{ClusterMetrics, CoordSink, Telemetry};

/// One replica's serving shape — its share of the heterogeneous fleet.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaSpec {
    /// Batch composition this replica runs.
    pub mode: BatchMode,
    /// Fixed mode: maximum requests fused per engine batch.
    pub max_batch: usize,
    /// Continuous mode: UNet slots packed per iteration.
    pub slot_budget: usize,
    /// Worker threads (fixed) / cohorts (continuous).
    pub workers: usize,
    /// Fixed mode: batch fill window, milliseconds.
    pub batch_wait_ms: u64,
}

impl Default for ReplicaSpec {
    fn default() -> Self {
        let c = CoordinatorConfig::default();
        ReplicaSpec {
            mode: c.mode,
            max_batch: c.max_batch,
            slot_budget: c.slot_budget,
            workers: c.workers,
            batch_wait_ms: c.batch_wait.as_millis() as u64,
        }
    }
}

impl ReplicaSpec {
    /// The spec the `[server]` section implies — the homogeneous default
    /// every `[cluster.replica.N]` override starts from.
    pub fn from_server(cfg: &ServerConfig) -> ReplicaSpec {
        ReplicaSpec {
            mode: cfg.mode,
            max_batch: cfg.max_batch,
            slot_budget: cfg.slot_budget,
            workers: cfg.workers,
            batch_wait_ms: cfg.batch_wait_ms,
        }
    }

    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            mode: self.mode,
            max_batch: self.max_batch,
            slot_budget: self.slot_budget,
            workers: self.workers,
            batch_wait: Duration::from_millis(self.batch_wait_ms),
            // the cache tiers are cluster-scoped, not a replica-shape
            // concern: ReplicaSet::start_inner injects ClusterConfig.cache
            cache: CacheConfig::default(),
        }
    }

    /// Routing weight: UNet slots this replica advances per iteration —
    /// the denominator that makes outstanding-eval loads comparable
    /// across heterogeneous replicas. Continuous replicas advance their
    /// slot budget per cohort iteration; fixed replicas advance up to
    /// `2 × max_batch` (every sample may run a dual step) per worker.
    pub fn capacity_weight(&self) -> f64 {
        match self.mode {
            BatchMode::Continuous => (self.slot_budget * self.workers) as f64,
            BatchMode::Fixed => (2 * self.max_batch * self.workers) as f64,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_batch == 0 || self.workers == 0 {
            return Err(Error::Config("replica max_batch and workers must be >= 1".into()));
        }
        if self.mode == BatchMode::Continuous && self.slot_budget < 2 {
            return Err(Error::Config(format!(
                "replica slot_budget {} must be >= 2 (a dual step costs 2 slots)",
                self.slot_budget
            )));
        }
        Ok(())
    }
}

/// The effective routing weight of one replica: its shape-derived
/// capacity, scaled — when the fleet is priced — by the replica's
/// measured per-slot speed (`2 / dual_step_ms`, the analytic slot rate a
/// 1-ms-per-eval replica would have). Loads are outstanding
/// *fleet-reference* microseconds, so dividing by a weight that carries
/// the replica's own speed steers proportionally more work to faster
/// hardware. With one shared table the scale factor is the same constant
/// everywhere and placements match unit-slot routing bit-exactly.
fn route_weight(spec: &ReplicaSpec, table: Option<&CostTable>) -> f64 {
    match table {
        Some(t) => spec.capacity_weight() * 2.0 / t.sample_step_ms(StepMode::Dual),
        None => spec.capacity_weight(),
    }
}

/// The `[cluster]` configuration: how many replicas, their shapes, and
/// the routing policy.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub replicas: Vec<ReplicaSpec>,
    pub route: RoutePolicy,
    /// Seed for the router's two-choice sampling: placements are a pure
    /// function of this seed and the submission sequence.
    pub route_seed: u64,
    /// Amortization tiers (DESIGN.md §13), instantiated **per replica**
    /// (request cache + shared uncond cache are replica-scoped; the
    /// router keeps identical keys together via cache affinity).
    pub cache: CacheConfig,
    /// Measured cost tables (DESIGN.md §15). Empty: routing stays in
    /// analytic UNet-eval units. One table: the whole fleet shares it
    /// (pricing is a pure relabeling — placements are preserved
    /// bit-exactly). `n` tables: replica `i` uses table `i % n`, so a
    /// heterogeneous fleet routes by each replica's *measured* speed.
    /// Table 0 is always the fleet reference that prices job costs.
    /// Injected programmatically (from the `[cost]` section by the
    /// server wiring), like `cache` — not a `[cluster]` TOML key.
    pub cost_tables: Vec<Arc<CostTable>>,
    /// Per-replica continuous-batcher millisecond budget
    /// ([`crate::coordinator::ContinuousBatcher::with_ms_budget`]);
    /// `0.0` disables the ms admission tier. Requires `cost_tables`.
    pub cost_budget_ms: f64,
    /// Compiled Pareto frontiers (DESIGN.md §16). Empty: QoS admission
    /// keeps the legacy analytic widening. One frontier: the fleet
    /// shares it. `n` frontiers: replica `i` searches frontier `i % n`
    /// (a heterogeneous fleet tuned per backend). Injected
    /// programmatically (from the `[planner]` section by the server
    /// wiring), like `cost_tables` — not a `[cluster]` TOML key.
    pub planners: Vec<Arc<PlanSearch>>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: vec![ReplicaSpec::default()],
            route: RoutePolicy::PlanCost,
            route_seed: 0,
            cache: CacheConfig::default(),
            cost_tables: Vec::new(),
            cost_budget_ms: 0.0,
            planners: Vec::new(),
        }
    }
}

impl ClusterConfig {
    /// A homogeneous fleet of `n` copies of `spec`.
    pub fn homogeneous(n: usize, spec: ReplicaSpec) -> ClusterConfig {
        ClusterConfig { replicas: vec![spec; n.max(1)], ..ClusterConfig::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.replicas.is_empty() {
            return Err(Error::Config("cluster needs at least one replica".into()));
        }
        for (i, spec) in self.replicas.iter().enumerate() {
            spec.validate()
                .map_err(|e| Error::Config(format!("cluster replica {i}: {e}")))?;
        }
        // every installed table must price a single sample (batch 1,
        // both step modes) — that price is the routing weight scale and
        // the per-sample scheduling currency, so a table that cannot
        // resolve it would silently fall back on every placement
        for (k, t) in self.cost_tables.iter().enumerate() {
            for mode in [StepMode::Dual, StepMode::Single] {
                if !t.covers(1, mode) {
                    return Err(Error::Config(format!(
                        "cluster cost table {k} cannot price a batch-1 {} step \
                         (calibrated buckets: {:?})",
                        mode.name(),
                        t.batches()
                    )));
                }
            }
        }
        if self.cost_budget_ms != 0.0 {
            if !self.cost_budget_ms.is_finite() || self.cost_budget_ms < 0.0 {
                return Err(Error::Config(format!(
                    "cluster cost_budget_ms {} must be finite and >= 0",
                    self.cost_budget_ms
                )));
            }
            if self.cost_tables.is_empty() {
                return Err(Error::Config(
                    "cluster cost_budget_ms requires cost tables (nothing prices the budget)"
                        .into(),
                ));
            }
            for i in 0..self.replicas.len() {
                let dual = self
                    .cost_table_for(i)
                    .expect("tables non-empty")
                    .sample_step_ms(StepMode::Dual);
                if self.cost_budget_ms < dual {
                    return Err(Error::Config(format!(
                        "cluster cost_budget_ms {} cannot admit even one dual-guidance \
                         sample on replica {i} (a dual step measures {dual} ms)",
                        self.cost_budget_ms
                    )));
                }
            }
        }
        Ok(())
    }

    /// The cost table replica `i` runs against: `None` while the fleet
    /// is unpriced, table `i % n` otherwise.
    pub fn cost_table_for(&self, i: usize) -> Option<&Arc<CostTable>> {
        if self.cost_tables.is_empty() {
            None
        } else {
            Some(&self.cost_tables[i % self.cost_tables.len()])
        }
    }

    /// The frontier replica `i` searches at admission: `None` while the
    /// fleet runs the legacy actuator, frontier `i % n` otherwise.
    pub fn planner_for(&self, i: usize) -> Option<&Arc<PlanSearch>> {
        if self.planners.is_empty() {
            None
        } else {
            Some(&self.planners[i % self.planners.len()])
        }
    }

    /// Build from the `[cluster]` TOML section (plus per-replica
    /// `[cluster.replica.N]` override sections), defaulting each replica
    /// to the `[server]` shape. Returns `None` when no `[cluster]`
    /// section exists — the deployment stays a plain single coordinator.
    pub fn from_toml(doc: &TomlDoc, base: &ServerConfig) -> Result<Option<ClusterConfig>> {
        if doc.section("cluster").is_none() {
            // an override section without the [cluster] switch is an
            // operator error, not a silent no-op
            if let Some(orphan) = doc
                .section_names()
                .find(|name| name.starts_with("cluster.replica."))
            {
                return Err(Error::Config(format!(
                    "[{orphan}] requires a [cluster] section"
                )));
            }
            return Ok(None);
        }
        let n = match doc.get("cluster", "replicas") {
            Some(v) => v
                .as_usize()
                .ok_or_else(|| Error::Config("cluster replicas must be int >= 1".into()))?,
            None => 1,
        };
        if n == 0 {
            return Err(Error::Config("cluster replicas must be >= 1".into()));
        }
        let route = match doc.get("cluster", "route") {
            Some(v) => RoutePolicy::parse(
                v.as_str().ok_or_else(|| Error::Config("cluster route must be string".into()))?,
            )?,
            None => RoutePolicy::PlanCost,
        };
        let route_seed = match doc.get("cluster", "route_seed") {
            Some(v) => v
                .as_i64()
                .ok_or_else(|| Error::Config("cluster route_seed must be int".into()))?
                as u64,
            None => 0,
        };
        // per-replica overrides: [cluster.replica.N] with any subset of
        // the [server] batching keys
        let mut replicas = Vec::with_capacity(n);
        for i in 0..n {
            let mut spec = ReplicaSpec::from_server(base);
            let sec = format!("cluster.replica.{i}");
            if let Some(v) = doc.get(&sec, "mode") {
                spec.mode = BatchMode::parse(
                    v.as_str()
                        .ok_or_else(|| Error::Config(format!("{sec} mode must be string")))?,
                )?;
            }
            if let Some(v) = doc.get(&sec, "max_batch") {
                spec.max_batch = v
                    .as_usize()
                    .ok_or_else(|| Error::Config(format!("{sec} max_batch must be int")))?;
            }
            if let Some(v) = doc.get(&sec, "slot_budget") {
                spec.slot_budget = v
                    .as_usize()
                    .ok_or_else(|| Error::Config(format!("{sec} slot_budget must be int")))?;
            }
            if let Some(v) = doc.get(&sec, "workers") {
                spec.workers = v
                    .as_usize()
                    .ok_or_else(|| Error::Config(format!("{sec} workers must be int")))?;
            }
            if let Some(v) = doc.get(&sec, "batch_wait_ms") {
                spec.batch_wait_ms = v
                    .as_i64()
                    .ok_or_else(|| Error::Config(format!("{sec} batch_wait_ms must be int")))?
                    as u64;
            }
            replicas.push(spec);
        }
        // overrides addressing replicas that don't exist are operator
        // errors (a typo'd index must not silently fall back to defaults)
        for name in doc.section_names() {
            if let Some(idx) = name.strip_prefix("cluster.replica.") {
                match idx.parse::<usize>() {
                    Ok(i) if i < n => {}
                    _ => {
                        return Err(Error::Config(format!(
                            "[{name}] addresses no replica (cluster has {n})"
                        )))
                    }
                }
            }
        }
        let cfg = ClusterConfig {
            replicas,
            route,
            route_seed,
            cache: CacheConfig::from_toml(doc)?,
            // priced routing needs a loaded manifest, so the tables (and
            // the ms budget they denominate) are injected by the server
            // wiring from the [cost] section, not parsed here — the
            // frontiers likewise from the [planner] section
            cost_tables: Vec::new(),
            cost_budget_ms: 0.0,
            planners: Vec::new(),
        };
        cfg.validate()?;
        Ok(Some(cfg))
    }
}

/// Per-request placement trace — which replica(s) served the request, in
/// order (more than one entry means it was requeued after a failure).
#[derive(Debug, Clone)]
pub struct PlacementTrace {
    placed: Arc<Mutex<Vec<usize>>>,
}

impl PlacementTrace {
    pub fn history(&self) -> Vec<usize> {
        self.placed.lock().unwrap().clone()
    }

    /// The replica that (last) served the request.
    pub fn replica(&self) -> Option<usize> {
        self.placed.lock().unwrap().last().copied()
    }
}

struct ClusterJob {
    req: GenerationRequest,
    meta: QosMeta,
    respond: Sender<(Result<GenerationOutput>, Duration)>,
    /// Replicas this job must not be placed on again (requeue history).
    excluded: Vec<usize>,
    /// The routing weight: plan-compiled total UNet evals, or — when the
    /// fleet carries cost tables — the plan's measured cost against the
    /// fleet-reference table, in integer microseconds.
    cost: u64,
    placed: Arc<Mutex<Vec<usize>>>,
    /// Cluster-level submission instant: the zero point for the
    /// client-visible latency and the end-to-end deadline budget, which
    /// must both survive requeues (a failover does not reset the clock).
    submitted_at: Instant,
    /// The deadline as admitted (post any QoS default). `meta.deadline`
    /// is rewritten to the *remaining* budget on every requeue; this is
    /// the immutable total it is computed from.
    original_deadline: Option<Duration>,
    /// Canonical cache key (Some when a keyed cache tier is on): the
    /// router's affinity signal — identical keys prefer the replica
    /// whose cache already holds (or is computing) the entry.
    key: Option<String>,
    /// Watched submissions: the client-facing progress sender, cloned
    /// into every replica leg so events keep flowing across a requeue.
    watch: Option<WatchSink>,
    /// Watched submissions: the one cancel flag shared by the client
    /// handle and every replica leg (a failover must stay cancellable).
    cancel: Option<Arc<AtomicBool>>,
}

/// Bounded key→replica affinity (insertion-order eviction): routing
/// identical keys to the same replica is what makes per-replica request
/// caches and in-flight dedup effective without a global shared cache.
struct Affinity {
    cap: usize,
    map: HashMap<String, usize>,
    order: VecDeque<String>,
}

impl Affinity {
    fn new(cap: usize) -> Affinity {
        Affinity { cap: cap.max(1), map: HashMap::new(), order: VecDeque::new() }
    }

    fn get(&self, key: &str) -> Option<usize> {
        self.map.get(key).copied()
    }

    fn note(&mut self, key: &str, replica: usize) {
        if self.map.insert(key.to_string(), replica).is_none() {
            self.order.push_back(key.to_string());
            while self.order.len() > self.cap {
                if let Some(old) = self.order.pop_front() {
                    self.map.remove(&old);
                }
            }
        }
    }
}

struct RelayItem {
    inner: Ticket,
    job: ClusterJob,
}

struct Replica {
    id: usize,
    spec: ReplicaSpec,
    coordinator: Arc<Coordinator>,
    /// Outstanding plan-compiled UNet evals placed here — the router's
    /// load signal. Reserved at dispatch, released when the relay
    /// observes the outcome.
    outstanding_evals: AtomicU64,
    healthy: AtomicBool,
    /// Requests this replica was chosen for (incl. requeues onto it).
    routed: AtomicU64,
    relay_tx: Mutex<Option<Sender<RelayItem>>>,
}

struct Core {
    replicas: Vec<Replica>,
    router: Mutex<Router>,
    route: RoutePolicy,
    /// Measured cost tables (empty = analytic unit routing). Table 0 is
    /// the fleet reference every job is priced against.
    cost_tables: Vec<Arc<CostTable>>,
    /// Compiled frontiers (empty = legacy actuator; DESIGN.md §16). Kept
    /// for the stats dedup — a fleet-shared frontier's counters must not
    /// be summed once per replica referencing it.
    planners: Vec<Arc<PlanSearch>>,
    qos: Option<Arc<dyn QosPolicy>>,
    /// Cluster-owned latency histogram: every completion is recorded
    /// here by the relays, so the aggregate percentiles are exact (they
    /// cannot be merged from per-replica snapshots).
    latency: Mutex<LatencyHistogram>,
    submitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    deadline_missed: AtomicU64,
    cancelled: AtomicU64,
    requeued: AtomicU64,
    ejected: AtomicU64,
    /// Outstanding requests across the whole cluster (the aggregate
    /// depth the QoS policy admits against).
    pending: AtomicU64,
    pending_max: AtomicU64,
    draining: AtomicBool,
    /// Cluster-layer telemetry (DESIGN.md §12). The cluster owns the
    /// span terminals: replica coordinators run with non-terminal sinks
    /// so a requeued failover still ends in exactly one terminal event.
    metrics: Option<ClusterMetrics>,
    /// Cache-key → replica affinity; Some only when a keyed cache tier
    /// is configured.
    affinity: Option<Mutex<Affinity>>,
}

impl Core {
    /// [`ClusterConfig::cost_table_for`] over the installed tables.
    fn cost_table_for(&self, i: usize) -> Option<&CostTable> {
        if self.cost_tables.is_empty() {
            None
        } else {
            Some(&self.cost_tables[i % self.cost_tables.len()])
        }
    }

    /// Route + enqueue one admitted job, retrying across replicas until
    /// one accepts; on total failure the job is handed back with the
    /// error so the caller decides who answers the client. Returns the
    /// replica-side cache outcome (hit/dedup/miss), known synchronously
    /// at enqueue time.
    fn dispatch(
        &self,
        mut job: ClusterJob,
        requeued_from: Option<usize>,
    ) -> std::result::Result<Option<crate::cache::CacheOutcome>, (ClusterJob, Error)> {
        loop {
            // cache affinity first: an identical key prefers the replica
            // whose request cache / in-flight dedup already knows it —
            // the router only decides when affinity can't (cold key, or
            // the preferred replica is unhealthy/excluded)
            let affine = match (&self.affinity, &job.key) {
                (Some(aff), Some(k)) => aff.lock().unwrap().get(k).filter(|&rid| {
                    self.replicas[rid].healthy.load(Ordering::SeqCst)
                        && !job.excluded.contains(&rid)
                }),
                _ => None,
            };
            let target = affine.or_else(|| {
                let loads: Vec<Option<u64>> = self
                    .replicas
                    .iter()
                    .map(|r| {
                        if r.healthy.load(Ordering::SeqCst) && !job.excluded.contains(&r.id) {
                            Some(r.outstanding_evals.load(Ordering::Relaxed))
                        } else {
                            None
                        }
                    })
                    .collect();
                self.router.lock().unwrap().place(&loads)
            });
            let Some(id) = target else {
                return Err((
                    job,
                    Error::Coordinator("no healthy replica can take the request".into()),
                ));
            };
            let replica = &self.replicas[id];
            // reserve the load before enqueueing so concurrent placements
            // see each other's reservations
            let outstanding =
                replica.outstanding_evals.fetch_add(job.cost, Ordering::Relaxed) + job.cost;
            let watch = match (&job.watch, &job.cancel) {
                (Some(w), Some(c)) => Some((w.clone(), Arc::clone(c))),
                _ => None,
            };
            match replica
                .coordinator
                .submit_preadmitted_watched(job.req.clone(), job.meta, watch)
            {
                Ok(inner) => {
                    replica.routed.fetch_add(1, Ordering::Relaxed);
                    if let Some(m) = &self.metrics {
                        m.on_placed(job.meta.trace, id, outstanding, requeued_from);
                    }
                    if let (Some(aff), Some(k)) = (&self.affinity, &job.key) {
                        aff.lock().unwrap().note(k, id);
                    }
                    let outcome = inner.cache_outcome();
                    job.placed.lock().unwrap().push(id);
                    let item = RelayItem { inner, job };
                    let failed_item = {
                        let guard = replica.relay_tx.lock().unwrap();
                        match guard.as_ref() {
                            Some(tx) => tx.send(item).err().map(|e| e.0),
                            None => Some(item),
                        }
                    };
                    match failed_item {
                        None => return Ok(outcome),
                        Some(RelayItem { inner, job: mut back }) => {
                            // relay already closed (shutdown race): undo
                            // the reservation, drop the inner ticket (the
                            // replica sheds the job during its drain) and
                            // try elsewhere
                            drop(inner);
                            let left = replica
                                .outstanding_evals
                                .fetch_sub(back.cost, Ordering::Relaxed)
                                - back.cost;
                            if let Some(m) = &self.metrics {
                                m.on_outstanding(id, left);
                            }
                            back.placed.lock().unwrap().pop();
                            back.excluded.push(id);
                            job = back;
                        }
                    }
                }
                Err(e) => {
                    let left =
                        replica.outstanding_evals.fetch_sub(job.cost, Ordering::Relaxed) - job.cost;
                    if let Some(m) = &self.metrics {
                        m.on_outstanding(id, left);
                    }
                    // a request-level error would fail identically on
                    // every replica — surface it; lifecycle errors
                    // (draining/stopped replica) exclude this replica and
                    // try the next one
                    if matches!(e, Error::Request(_) | Error::Config(_)) {
                        return Err((job, e));
                    }
                    job.excluded.push(id);
                }
            }
        }
    }
}

/// The running replica set.
pub struct ReplicaSet {
    core: Arc<Core>,
    relays: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ReplicaSet {
    /// Spawn one coordinator per replica spec (no QoS: every request is
    /// admitted) plus the relay threads that forward completions and
    /// requeue failures.
    pub fn start(engine: Arc<Engine>, config: ClusterConfig) -> Result<Arc<ReplicaSet>> {
        Self::start_inner(engine, config, None, None)
    }

    /// Spawn with a cluster-level [`QosPolicy`]: admission is decided
    /// here against the *aggregate* outstanding depth, and the same
    /// policy object is installed in every replica coordinator so worker
    /// feedback (service times, slot occupancy, deadline misses) merges
    /// across the fleet.
    pub fn start_qos(
        engine: Arc<Engine>,
        config: ClusterConfig,
        qos: Arc<dyn QosPolicy>,
    ) -> Result<Arc<ReplicaSet>> {
        Self::start_inner(engine, config, Some(qos), None)
    }

    /// The superset entry point: optional QoS *and* an optional
    /// [`Telemetry`] hub (DESIGN.md §12). The cluster wires each replica
    /// coordinator with a non-terminal `replicaN`-scoped sink and keeps
    /// span-terminal ownership in its relays, so a request requeued
    /// across replicas still ends in exactly one terminal event.
    pub fn start_full(
        engine: Arc<Engine>,
        config: ClusterConfig,
        qos: Option<Arc<dyn QosPolicy>>,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Result<Arc<ReplicaSet>> {
        Self::start_inner(engine, config, qos, telemetry)
    }

    fn start_inner(
        engine: Arc<Engine>,
        config: ClusterConfig,
        qos: Option<Arc<dyn QosPolicy>>,
        telemetry: Option<Arc<Telemetry>>,
    ) -> Result<Arc<ReplicaSet>> {
        config.validate()?;
        let weights: Vec<f64> = config
            .replicas
            .iter()
            .enumerate()
            .map(|(i, s)| route_weight(s, config.cost_table_for(i).map(Arc::as_ref)))
            .collect();
        let router = Router::new(config.route, weights, config.route_seed)?;
        let mut replicas = Vec::with_capacity(config.replicas.len());
        let mut relay_rxs = Vec::with_capacity(config.replicas.len());
        for (id, spec) in config.replicas.iter().enumerate() {
            // replica sinks never close spans — the relay owns terminals
            let sink = telemetry
                .as_ref()
                .map(|t| CoordSink::new(t, &format!("replica{id}"), false));
            // every replica gets its own instance of the cluster's cache
            // tiers (replica-scoped caches + affinity routing, not one
            // global cache with cross-replica contention)
            let mut coord_cfg = spec.coordinator_config();
            coord_cfg.cache = config.cache.clone();
            // each replica coordinator carries its own table: its stats
            // report the measured model ratio, its QoS view (the shared
            // policy) learns the measured shed ratio, and a nonzero
            // budget prices its continuous batcher in milliseconds
            coord_cfg.cost_table = config.cost_table_for(id).cloned();
            coord_cfg.cost_budget_ms = config.cost_budget_ms;
            // each replica coordinator attaches its frontier to the
            // shared QoS policy (write-once: the first replica wins,
            // which for the common one-frontier fleet is the frontier)
            coord_cfg.planner = config.planner_for(id).cloned();
            let coordinator =
                Coordinator::start_full(Arc::clone(&engine), coord_cfg, qos.clone(), sink);
            let (tx, rx) = mpsc::channel::<RelayItem>();
            replicas.push(Replica {
                id,
                spec: spec.clone(),
                coordinator,
                outstanding_evals: AtomicU64::new(0),
                healthy: AtomicBool::new(true),
                routed: AtomicU64::new(0),
                relay_tx: Mutex::new(Some(tx)),
            });
            relay_rxs.push(rx);
        }
        let core = Arc::new(Core {
            replicas,
            router: Mutex::new(router),
            route: config.route,
            cost_tables: config.cost_tables.clone(),
            planners: config.planners.clone(),
            qos,
            latency: Mutex::new(LatencyHistogram::new()),
            submitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            deadline_missed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            ejected: AtomicU64::new(0),
            pending: AtomicU64::new(0),
            pending_max: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            metrics: telemetry
                .as_ref()
                .map(|t| ClusterMetrics::new(t, config.replicas.len())),
            affinity: config
                .cache
                .keyed()
                .then(|| Mutex::new(Affinity::new(1024))),
        });
        let relays = relay_rxs
            .into_iter()
            .enumerate()
            .map(|(id, rx)| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("sgd-relay-{id}"))
                    .spawn(move || relay_loop(core, id, rx))
                    .expect("spawn relay")
            })
            .collect();
        Ok(Arc::new(ReplicaSet { core, relays: Mutex::new(relays) }))
    }

    pub fn replicas(&self) -> usize {
        self.core.replicas.len()
    }

    /// The telemetry hub this cluster reports into, when observed. The
    /// server front-end serves `{"op":"metrics"}` / `{"op":"trace"}`
    /// from here.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.core.metrics.as_ref().map(|m| m.telemetry())
    }

    pub fn route(&self) -> RoutePolicy {
        self.core.route
    }

    /// Enqueue a request; see [`ReplicaSet::submit_traced`].
    pub fn submit(&self, req: GenerationRequest) -> Result<Ticket> {
        self.submit_qos(req, QosMeta::default())
    }

    /// Enqueue with serving metadata. Cluster-level QoS admission (when
    /// installed) runs against the aggregate outstanding depth; the
    /// admitted request is routed by its compiled plan cost.
    pub fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        Ok(self.submit_traced(req, meta)?.0)
    }

    /// Watched submission through the cluster: the progress stream and
    /// cancel handle span replica legs — a request requeued after a
    /// replica death keeps streaming to (and stays cancellable by) the
    /// same client-side handles.
    pub fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        let (ptx, progress) = mpsc::channel();
        let cancel = CancelHandle::new();
        let sink = WatchSink { tx: ptx, preview_every: watch.preview_every };
        let (ticket, _) = self.submit_traced_inner(req, meta, Some((sink, cancel.flag())))?;
        Ok(Watched { ticket, progress, cancel })
    }

    /// [`ReplicaSet::submit_qos`] plus a [`PlacementTrace`] recording
    /// which replica(s) the request is served on — the observability
    /// hook the determinism and failure tests key on.
    pub fn submit_traced(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
    ) -> Result<(Ticket, PlacementTrace)> {
        self.submit_traced_inner(req, meta, None)
    }

    fn submit_traced_inner(
        &self,
        mut req: GenerationRequest,
        mut meta: QosMeta,
        watch: Option<(WatchSink, Arc<AtomicBool>)>,
    ) -> Result<(Ticket, PlacementTrace)> {
        req.validate()?;
        let core = &self.core;
        if core.draining.load(Ordering::SeqCst) {
            return Err(Error::Coordinator("cluster is draining".into()));
        }
        // the cluster is the front door: it opens the trace span, and
        // meta carries the id through every replica leg and requeue
        if meta.trace.is_none() {
            if let Some(m) = &core.metrics {
                meta.trace = m.begin_trace();
            }
        }
        // reserve the aggregate-depth slot before admission (same exact-
        // bound argument as Coordinator::submit_qos)
        let depth_before = core.pending.fetch_add(1, Ordering::Relaxed) as usize;
        if let Some(q) = &core.qos {
            match q.admit(&mut req, &mut meta, depth_before) {
                AdmissionDecision::Admit => {}
                AdmissionDecision::Reject(reason) => {
                    core.pending.fetch_sub(1, Ordering::Relaxed);
                    core.rejected.fetch_add(1, Ordering::Relaxed);
                    if let Some(m) = &core.metrics {
                        m.on_rejected(meta.trace, reason.code(), &reason.message());
                    }
                    return Err(Error::Rejected {
                        code: reason.code(),
                        reason: reason.message(),
                    });
                }
            }
        }
        core.pending_max.fetch_max(depth_before as u64 + 1, Ordering::Relaxed);
        // the routing weight is the *post-rewrite* plan cost: what the
        // replica will actually execute after any QoS actuation. Priced
        // fleets route in measured microseconds of the reference table
        // (integer, so reserve/release arithmetic stays exact)
        let cost = match req.plan() {
            Ok(p) => match core.cost_tables.first() {
                Some(t) => (p.cost_ms(t) * 1000.0).round() as u64,
                None => p.total_unet_evals() as u64,
            },
            Err(e) => {
                core.pending.fetch_sub(1, Ordering::Relaxed);
                if let Some(m) = &core.metrics {
                    m.on_shed(meta.trace, "invalid");
                }
                return Err(e);
            }
        };
        if let Some(m) = &core.metrics {
            m.on_admitted(meta.trace, meta.priority.name(), depth_before + 1);
        }
        let (tx, rx) = mpsc::channel();
        let placed = Arc::new(Mutex::new(Vec::new()));
        // the canonical key doubles as the affinity signal; plan() just
        // succeeded above, so key derivation cannot fail here. Watched
        // jobs stay keyless — they bypass the replica cache tiers, so
        // pinning them to a cache-affine replica buys nothing
        let key = core
            .affinity
            .is_some()
            .then(|| watch.is_none().then(|| canonical_key(&req).ok()))
            .flatten()
            .flatten();
        let (watch_sink, cancel_flag) = match watch {
            Some((w, c)) => (Some(w), Some(c)),
            None => (None, None),
        };
        let job = ClusterJob {
            req,
            respond: tx,
            excluded: Vec::new(),
            cost,
            placed: Arc::clone(&placed),
            submitted_at: Instant::now(),
            original_deadline: meta.deadline,
            key,
            watch: watch_sink,
            cancel: cancel_flag,
            meta,
        };
        let trace = meta.trace;
        match core.dispatch(job, None) {
            Ok(outcome) => {
                core.submitted.fetch_add(1, Ordering::Relaxed);
                let ticket = Ticket::from_rx(rx, trace);
                if let Some(o) = outcome {
                    let _ = ticket.outcome_cell().set(o);
                }
                Ok((ticket, PlacementTrace { placed }))
            }
            Err((job, e)) => {
                drop(job);
                core.pending.fetch_sub(1, Ordering::Relaxed);
                if let Some(m) = &core.metrics {
                    m.on_shed(trace, "no_replica");
                }
                Err(e)
            }
        }
    }

    /// Submit + wait.
    pub fn generate(&self, req: GenerationRequest) -> Result<GenerationOutput> {
        self.submit(req)?.wait()
    }

    /// Eject replica `id`: the router stops placing on it immediately,
    /// its executing work drains, and its queued jobs come back as 503
    /// sheds which the relay requeues onto surviving replicas. Blocks
    /// until the replica's coordinator has shut down. Idempotent.
    pub fn kill(&self, id: usize) -> Result<()> {
        let replica = self
            .core
            .replicas
            .get(id)
            .ok_or_else(|| Error::Config(format!("no replica {id}")))?;
        if replica.healthy.swap(false, Ordering::SeqCst) {
            self.core.ejected.fetch_add(1, Ordering::Relaxed);
            if let Some(m) = &self.core.metrics {
                m.on_ejected(id);
            }
            replica.coordinator.shutdown();
        }
        Ok(())
    }

    /// Snapshot the merged cluster view plus the per-replica breakdown.
    pub fn stats(&self) -> ClusterStats {
        let core = &self.core;
        let replicas: Vec<ReplicaStats> = core
            .replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                id: r.id,
                healthy: r.healthy.load(Ordering::SeqCst),
                routed: r.routed.load(Ordering::Relaxed),
                outstanding_evals: r.outstanding_evals.load(Ordering::Relaxed),
                capacity_weight: r.spec.capacity_weight(),
                route_weight: route_weight(&r.spec, core.cost_table_for(i)),
                coordinator: r.coordinator.stats(),
            })
            .collect();
        // distinct tables only: a fleet-wide shared table (the common
        // case) must not have its fallback counter summed once per
        // replica referencing it
        let mut seen: Vec<*const CostTable> = Vec::new();
        let mut cost_fallbacks = 0u64;
        for t in &core.cost_tables {
            let p = Arc::as_ptr(t);
            if !seen.contains(&p) {
                seen.push(p);
                cost_fallbacks += t.fallback_count();
            }
        }
        // same discipline for the frontiers: a fleet-shared PlanSearch
        // carries one set of global counters
        let mut seen_planners: Vec<*const PlanSearch> = Vec::new();
        let mut planner = crate::guidance::PlannerSnapshot::default();
        for s in &core.planners {
            let p = Arc::as_ptr(s);
            if !seen_planners.contains(&p) {
                seen_planners.push(p);
                let snap = s.snapshot();
                planner.searches += snap.searches;
                planner.frontier_hits += snap.frontier_hits;
                planner.fallbacks += snap.fallbacks;
                planner.floor_clamps += snap.floor_clamps;
            }
        }
        let actuator_fraction = core
            .qos
            .as_ref()
            .map(|q| q.qos_snapshot().actuator_fraction)
            .unwrap_or(0.0);
        let latency = core.latency.lock().unwrap();
        ClusterStats {
            route: core.route,
            healthy_replicas: replicas.iter().filter(|r| r.healthy).count(),
            submitted: core.submitted.load(Ordering::Relaxed),
            completed: core.completed.load(Ordering::Relaxed),
            failed: core.failed.load(Ordering::Relaxed),
            rejected: core.rejected.load(Ordering::Relaxed),
            deadline_missed: core.deadline_missed.load(Ordering::Relaxed),
            cancelled: core.cancelled.load(Ordering::Relaxed),
            requeued: core.requeued.load(Ordering::Relaxed),
            ejected: core.ejected.load(Ordering::Relaxed),
            queue_depth: core.pending.load(Ordering::Relaxed),
            queue_depth_max: core.pending_max.load(Ordering::Relaxed),
            outstanding_evals: replicas.iter().map(|r| r.outstanding_evals).sum(),
            cost_priced: !core.cost_tables.is_empty(),
            cost_fallbacks,
            planner_attached: !core.planners.is_empty(),
            planner_searches: planner.searches,
            planner_frontier_hits: planner.frontier_hits,
            planner_fallbacks: planner.fallbacks,
            planner_floor_clamps: planner.floor_clamps,
            cache_hits: replicas.iter().map(|r| r.coordinator.cache_hits).sum(),
            dedup_coalesced: replicas.iter().map(|r| r.coordinator.dedup_coalesced).sum(),
            batches: replicas.iter().map(|r| r.coordinator.batches).sum(),
            iterations: replicas.iter().map(|r| r.coordinator.iterations).sum(),
            joins: replicas.iter().map(|r| r.coordinator.joins).sum(),
            retires: replicas.iter().map(|r| r.coordinator.retires).sum(),
            drain_shed: replicas.iter().map(|r| r.coordinator.drain_shed).sum(),
            actuator_fraction,
            latency_ms_mean: latency.mean_ms(),
            latency_ms_p50: latency.quantile_ms(0.5),
            latency_ms_p90: latency.quantile_ms(0.9),
            latency_ms_max: latency.max_ms(),
            replicas,
        }
    }

    /// Graceful drain: stop accepting, finish executing work everywhere,
    /// shed what never started (503), resolve every ticket, join all
    /// threads.
    pub fn shutdown(&self) {
        self.core.draining.store(true, Ordering::SeqCst);
        // each coordinator drains (executing work completes, queued jobs
        // shed); relays forward those final outcomes without requeueing
        // because the cluster is draining
        for r in &self.core.replicas {
            r.coordinator.shutdown();
        }
        // closing the relay channels ends the relay threads once they
        // have drained every buffered item
        for r in &self.core.replicas {
            *r.relay_tx.lock().unwrap() = None;
        }
        let mut relays = self.relays.lock().unwrap();
        for h in relays.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl Submit for ReplicaSet {
    fn submit_watched(
        &self,
        req: GenerationRequest,
        meta: QosMeta,
        watch: WatchOptions,
    ) -> Result<Watched> {
        ReplicaSet::submit_watched(self, req, meta, watch)
    }

    // the unwatched path keeps cluster cache affinity + replica cache
    // tiers (the default adapter would bypass them)
    fn submit_qos(&self, req: GenerationRequest, meta: QosMeta) -> Result<Ticket> {
        ReplicaSet::submit_qos(self, req, meta)
    }
}

/// One relay thread per replica: observes every outcome of work placed
/// on that replica, releases its routed load, records completions into
/// the cluster-owned latency histogram, and **requeues** requeueable
/// failures (drain sheds, replica death) onto surviving replicas.
///
/// Outcomes are forwarded in *completion* order, not placement order
/// (the relay polls its in-flight set instead of blocking on one ticket
/// at a time): a short request placed after a long one resolves the
/// moment it retires, and its routed load frees immediately — the
/// router never steers around load that is already gone.
fn relay_loop(core: Arc<Core>, id: usize, rx: Receiver<RelayItem>) {
    let mut pending: Vec<RelayItem> = Vec::new();
    loop {
        // pull newly placed work without blocking while jobs are in flight
        let mut closed = false;
        loop {
            match rx.try_recv() {
                Ok(item) => pending.push(item),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    closed = true;
                    break;
                }
            }
        }
        if pending.is_empty() {
            if closed {
                return;
            }
            // idle: block until new work arrives (or the cluster closes)
            match rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => return,
            }
            continue;
        }
        // forward every resolved ticket, in completion order
        let mut progressed = false;
        let mut i = 0;
        while i < pending.len() {
            match pending[i].inner.try_wait_timed() {
                Some((result, _leg_latency)) => {
                    let item = pending.swap_remove(i);
                    relay_outcome(&core, id, item.job, result);
                    progressed = true;
                }
                None => i += 1,
            }
        }
        if !progressed {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// Handle one resolved inner ticket: release the routed load, then
/// forward, requeue, or fail. Latency and deadline accounting are
/// **end-to-end** from the cluster-level submission instant, so a
/// requeued request's first leg (queue time on the dead replica) stays
/// visible in the histogram and counts against its deadline budget.
fn relay_outcome(core: &Arc<Core>, id: usize, job: ClusterJob, result: Result<GenerationOutput>) {
    let left = core.replicas[id].outstanding_evals.fetch_sub(job.cost, Ordering::Relaxed) - job.cost;
    if let Some(m) = &core.metrics {
        m.on_outstanding(id, left);
    }
    let latency = job.submitted_at.elapsed();
    match result {
        Ok(out) => {
            core.latency.lock().unwrap().record(latency);
            core.completed.fetch_add(1, Ordering::Relaxed);
            core.pending.fetch_sub(1, Ordering::Relaxed);
            if let Some(m) = &core.metrics {
                m.on_retired(job.meta.trace, latency.as_secs_f64() * 1e3);
            }
            let _ = job.respond.send((Ok(out), latency));
        }
        Err(e) => {
            // a drain shed (503) or a dead/poisoned worker is the
            // replica's failure, not the request's — requeue onto the
            // survivors unless the whole cluster is going down. The
            // excluded list keeps a poison request from ping-ponging:
            // after it has failed on every replica once, the error
            // surfaces to the client. `Error::Engine` (typed per-sample
            // failure, e.g. cold shared-reuse cache) is deliberately
            // NOT requeueable: it would fail identically anywhere.
            // `Error::Cancelled` is NOT requeueable either — the client
            // abandoned the request; re-running it elsewhere would undo
            // the cancel.
            let requeueable =
                matches!(&e, Error::Rejected { code: 503, .. } | Error::Coordinator(_));
            if requeueable && !core.draining.load(Ordering::SeqCst) {
                let mut job = job;
                if !job.excluded.contains(&id) {
                    job.excluded.push(id);
                }
                // the deadline budget is end-to-end: the next leg only
                // gets what the failed leg left over (computed from the
                // immutable original so repeated failovers can't
                // double-subtract), and an exhausted budget is an honest
                // 504, not a fresh window
                if let Some(total) = job.original_deadline {
                    if total <= latency {
                        core.deadline_missed.fetch_add(1, Ordering::Relaxed);
                        core.pending.fetch_sub(1, Ordering::Relaxed);
                        if let Some(m) = &core.metrics {
                            m.on_expired(job.meta.trace);
                        }
                        let msg = format!(
                            "expired during replica failover after {:.0} ms (deadline {:.0} ms)",
                            latency.as_secs_f64() * 1e3,
                            total.as_secs_f64() * 1e3
                        );
                        let _ = job.respond.send((Err(Error::DeadlineExceeded(msg)), latency));
                        return;
                    }
                    job.meta.deadline = Some(total - latency);
                }
                // count before dispatching: the new home's relay may
                // resolve the ticket before this thread runs again, and
                // the requeue ledger must already balance then (the
                // requeued{from,to} span event is recorded at placement,
                // inside dispatch)
                core.requeued.fetch_add(1, Ordering::Relaxed);
                match core.dispatch(job, Some(id)) {
                    Ok(_) => {}
                    Err((job, err)) => {
                        core.requeued.fetch_sub(1, Ordering::Relaxed);
                        core.failed.fetch_add(1, Ordering::Relaxed);
                        core.pending.fetch_sub(1, Ordering::Relaxed);
                        if let Some(m) = &core.metrics {
                            m.on_shed(job.meta.trace, "exhausted");
                        }
                        let _ = job.respond.send((Err(err), latency));
                    }
                }
            } else {
                if matches!(e, Error::DeadlineExceeded(_)) {
                    core.deadline_missed.fetch_add(1, Ordering::Relaxed);
                    if let Some(m) = &core.metrics {
                        m.on_expired(job.meta.trace);
                    }
                } else if matches!(e, Error::Cancelled(_)) {
                    // the replica sink already counted it (non-terminal);
                    // the cluster owns the span terminal
                    core.cancelled.fetch_add(1, Ordering::Relaxed);
                    if let Some(m) = &core.metrics {
                        m.on_cancelled(job.meta.trace);
                    }
                } else {
                    core.failed.fetch_add(1, Ordering::Relaxed);
                    if let Some(m) = &core.metrics {
                        m.on_shed(job.meta.trace, "failed");
                    }
                }
                core.pending.fetch_sub(1, Ordering::Relaxed);
                let _ = job.respond.send((Err(e), latency));
            }
        }
    }
}

/// Per-replica stats entry: cluster-level routing state plus the
/// replica coordinator's own [`CoordinatorStats`].
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub id: usize,
    pub healthy: bool,
    /// Requests routed here (incl. requeues onto this replica).
    pub routed: u64,
    /// Outstanding routed load right now: plan-compiled UNet evals, or
    /// fleet-reference microseconds when the cluster is priced.
    pub outstanding_evals: u64,
    /// Shape-derived routing weight (normalizes outstanding load across
    /// mixed replica shapes).
    pub capacity_weight: f64,
    /// The weight the router actually divides by: `capacity_weight`,
    /// scaled by this replica's measured speed when the fleet is priced.
    pub route_weight: f64,
    pub coordinator: CoordinatorStats,
}

/// Merged cluster stats: cluster-owned counters (submission, admission,
/// completion, requeue/ejection, exact latency percentiles) plus the
/// summed per-replica execution counters and the full per-replica
/// breakdown.
#[derive(Debug, Clone, Default)]
pub struct ClusterStats {
    pub route: RoutePolicy,
    pub healthy_replicas: usize,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    /// Shed by cluster-level QoS admission.
    pub rejected: u64,
    pub deadline_missed: u64,
    /// Cancelled mid-flight by clients (never requeued — the client
    /// abandoned the request).
    pub cancelled: u64,
    /// Jobs moved to a surviving replica after a failure/ejection.
    pub requeued: u64,
    /// Replicas ejected via [`ReplicaSet::kill`].
    pub ejected: u64,
    /// Outstanding requests across the cluster right now.
    pub queue_depth: u64,
    pub queue_depth_max: u64,
    /// Summed outstanding routed load across replicas (plan-compiled
    /// UNet evals, or fleet-reference microseconds when priced).
    pub outstanding_evals: u64,
    /// True when routing runs in measured milliseconds (cost tables are
    /// installed; DESIGN.md §15).
    pub cost_priced: bool,
    /// Summed fallback-pricing events across the fleet's distinct cost
    /// tables — nonzero means a plan shape escaped the calibrated grid.
    pub cost_fallbacks: u64,
    /// True when QoS admission degrades along compiled frontiers
    /// (DESIGN.md §16).
    pub planner_attached: bool,
    /// Summed frontier lookups across the fleet's *distinct* frontiers
    /// (a shared frontier's counters are global, counted once).
    pub planner_searches: u64,
    pub planner_frontier_hits: u64,
    /// Lookups that missed every bucket (the legacy actuator answered).
    pub planner_fallbacks: u64,
    /// Demanded savings clamped at the quality floor's frontier point.
    pub planner_floor_clamps: u64,
    /// Summed replica request-cache hits (served without UNet work).
    pub cache_hits: u64,
    /// Summed replica dedup joins (coalesced onto in-flight identicals).
    pub dedup_coalesced: u64,
    /// Summed fixed-mode batches across replicas.
    pub batches: u64,
    /// Summed continuous-mode iterations across replicas.
    pub iterations: u64,
    pub joins: u64,
    pub retires: u64,
    /// Summed per-replica drain sheds (normally requeued, so clients see
    /// them only when the whole cluster drains).
    pub drain_shed: u64,
    pub actuator_fraction: f64,
    pub latency_ms_mean: f64,
    pub latency_ms_p50: f64,
    pub latency_ms_p90: f64,
    pub latency_ms_max: f64,
    pub replicas: Vec<ReplicaStats>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::guidance::WindowSpec;
    use crate::runtime::ModelStack;
    use crate::scheduler::SchedulerKind;

    fn engine() -> Arc<Engine> {
        Arc::new(Engine::new(Arc::new(ModelStack::synthetic()), EngineConfig::default()))
    }

    fn continuous(slot_budget: usize) -> ReplicaSpec {
        ReplicaSpec { mode: BatchMode::Continuous, slot_budget, ..ReplicaSpec::default() }
    }

    #[test]
    fn capacity_weight_models_replica_shape() {
        assert_eq!(continuous(8).capacity_weight(), 8.0);
        assert_eq!(
            ReplicaSpec { workers: 2, ..continuous(4) }.capacity_weight(),
            8.0
        );
        // fixed: every sample may need a dual step
        let fixed = ReplicaSpec { mode: BatchMode::Fixed, max_batch: 4, ..ReplicaSpec::default() };
        assert_eq!(fixed.capacity_weight(), 8.0);
        // validation mirrors the coordinator's bounds
        assert!(continuous(1).validate().is_err());
        assert!(ReplicaSpec { workers: 0, ..ReplicaSpec::default() }.validate().is_err());
        assert!(ClusterConfig::default().validate().is_ok());
        assert!(ClusterConfig { replicas: vec![], ..ClusterConfig::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn route_weight_scales_capacity_by_measured_speed() {
        // unpriced: the shape-derived capacity, unchanged
        assert_eq!(route_weight(&continuous(8), None), 8.0);
        // 0.5 ms/eval -> dual = 1.0 ms -> 2x the analytic 1-ms-unit rate
        let fast = CostTable::proportional(0.5, &[1]);
        assert_eq!(route_weight(&continuous(8), Some(&fast)), 16.0);
        // 1.0 ms/eval is exactly the analytic reference rate
        let reference = CostTable::proportional(1.0, &[1]);
        assert_eq!(route_weight(&continuous(8), Some(&reference)), 8.0);
        // a replica measured 4x slower carries a quarter of the weight
        let slow = CostTable::proportional(4.0, &[1]);
        assert_eq!(route_weight(&continuous(8), Some(&slow)), 2.0);
    }

    #[test]
    fn cost_config_validation_guards_pricing() {
        // a table that cannot price a batch-1 sample is an up-front error
        let sparse = Arc::new(CostTable::proportional(1.0, &[2, 4]));
        let cfg = ClusterConfig { cost_tables: vec![sparse], ..ClusterConfig::default() };
        assert!(cfg.validate().is_err());
        // a ms budget with nothing to price it is an error
        let cfg = ClusterConfig { cost_budget_ms: 10.0, ..ClusterConfig::default() };
        assert!(cfg.validate().is_err());
        // the budget must admit at least one dual sample on every replica
        let table = Arc::new(CostTable::proportional(10.0, &[1])); // dual = 20 ms
        let cfg = ClusterConfig {
            cost_tables: vec![Arc::clone(&table)],
            cost_budget_ms: 10.0,
            ..ClusterConfig::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = ClusterConfig {
            cost_tables: vec![Arc::clone(&table)],
            cost_budget_ms: 20.0,
            ..ClusterConfig::default()
        };
        assert!(cfg.validate().is_ok());
        // tables cycle across replicas: i % n
        let other = Arc::new(CostTable::proportional(2.0, &[1]));
        let cfg = ClusterConfig {
            replicas: vec![ReplicaSpec::default(); 3],
            cost_tables: vec![Arc::clone(&table), Arc::clone(&other)],
            ..ClusterConfig::default()
        };
        assert!(Arc::ptr_eq(cfg.cost_table_for(0).unwrap(), &table));
        assert!(Arc::ptr_eq(cfg.cost_table_for(1).unwrap(), &other));
        assert!(Arc::ptr_eq(cfg.cost_table_for(2).unwrap(), &table));
        assert!(ClusterConfig::default().cost_table_for(0).is_none());
    }

    #[test]
    fn shared_proportional_table_preserves_placements() {
        // the bit-exactness claim of DESIGN.md §15 at the routing layer:
        // one shared proportional table scales every job cost and every
        // replica weight by the same constants, so the priced router's
        // normalized-load comparisons are the unit router's, rescaled —
        // identical placements on an identical submission trace
        let specs = [
            continuous(8),
            continuous(4),
            ReplicaSpec { workers: 2, ..continuous(2) },
        ];
        let table = CostTable::proportional(0.5, &[1]);
        let unit_w: Vec<f64> = specs.iter().map(|s| route_weight(s, None)).collect();
        let priced_w: Vec<f64> =
            specs.iter().map(|s| route_weight(s, Some(&table))).collect();
        let mut unit_router = Router::new(RoutePolicy::PlanCost, unit_w, 42).unwrap();
        let mut priced_router = Router::new(RoutePolicy::PlanCost, priced_w, 42).unwrap();
        let evals: [u64; 12] = [80, 40, 60, 20, 100, 80, 10, 50, 70, 30, 90, 40];
        let mut unit_load = vec![0u64; specs.len()];
        let mut priced_load = vec![0u64; specs.len()];
        for &e in &evals {
            let u = unit_router
                .place(&unit_load.iter().map(|&l| Some(l)).collect::<Vec<_>>())
                .unwrap();
            let p = priced_router
                .place(&priced_load.iter().map(|&l| Some(l)).collect::<Vec<_>>())
                .unwrap();
            assert_eq!(u, p, "pricing changed a placement");
            // 0.5 ms/eval -> a job of e evals costs exactly 500e us
            unit_load[u] += e;
            priced_load[p] += 500 * e;
        }
        assert!(unit_load.iter().all(|&l| l > 0), "trace must exercise every replica");
    }

    #[test]
    fn priced_cluster_routes_in_measured_microseconds() {
        let table = Arc::new(CostTable::proportional(0.5, &[1]));
        let cfg = ClusterConfig {
            cost_tables: vec![Arc::clone(&table)],
            ..ClusterConfig::homogeneous(2, continuous(4))
        };
        let set = ReplicaSet::start(engine(), cfg).unwrap();
        let tickets: Vec<_> = (0..6)
            .map(|i| {
                let r = GenerationRequest::new(format!("c{i}"))
                    .steps(8)
                    .scheduler(SchedulerKind::Ddim)
                    .selective(WindowSpec::last(0.5))
                    .seed(i as u64)
                    .decode(false);
                set.submit_traced(r, QosMeta::default()).expect("submit")
            })
            .collect();
        for (t, _) in tickets {
            t.wait().expect("complete");
        }
        let stats = set.stats();
        assert_eq!(stats.completed, 6);
        assert!(stats.cost_priced);
        assert_eq!(stats.cost_fallbacks, 0, "batch-1 pricing must stay on the table");
        assert_eq!(stats.outstanding_evals, 0, "priced reservations release exactly");
        for r in &stats.replicas {
            // 0.5 ms/eval: dual = 1.0 ms -> every weight doubles
            assert_eq!(r.route_weight, r.capacity_weight * 2.0);
        }
        set.shutdown();
    }

    #[test]
    fn planner_counters_dedup_across_replicas() {
        use crate::guidance::{
            FrontierBucket, FrontierManifest, FrontierPoint, GuidanceSchedule, GuidanceStrategy,
            PlanSearch,
        };
        let bucket = FrontierBucket {
            steps: 50,
            full_cost_ms: 100.0,
            points: vec![
                FrontierPoint {
                    label: "last(0.8) × cond-only".into(),
                    schedule: GuidanceSchedule::Window(WindowSpec::last(0.8)),
                    strategy: GuidanceStrategy::CondOnly,
                    ssim: 0.8,
                    cost_ms: 60.0,
                },
                FrontierPoint {
                    label: "full CFG".into(),
                    schedule: GuidanceSchedule::none(),
                    strategy: GuidanceStrategy::CondOnly,
                    ssim: 1.0,
                    cost_ms: 100.0,
                },
            ],
        };
        let m =
            FrontierManifest::seal("t", "synthetic", "synthetic", "fp", 8, 7.5, 2, vec![bucket]);
        let search = Arc::new(PlanSearch::new(m).unwrap());
        let cfg = ClusterConfig {
            // the common fleet shape: both replicas share one frontier
            planners: vec![Arc::clone(&search), Arc::clone(&search)],
            ..ClusterConfig::homogeneous(2, continuous(4))
        };
        assert!(Arc::ptr_eq(cfg.planner_for(0).unwrap(), &search));
        assert!(Arc::ptr_eq(cfg.planner_for(2).unwrap(), &search));
        assert!(ClusterConfig::default().planner_for(0).is_none());
        let set = ReplicaSet::start(engine(), cfg).unwrap();
        // drive the shared frontier's global counters: one hit, one
        // bucket miss
        assert!(search.select(50, 0.1, 0.5).is_some());
        assert!(search.select(500, 0.1, 0.5).is_none());
        let stats = set.stats();
        assert!(stats.planner_attached);
        assert_eq!(
            stats.planner_searches, 2,
            "a shared frontier's counters are global — count once, not per replica"
        );
        assert_eq!(stats.planner_frontier_hits, 1);
        assert_eq!(stats.planner_fallbacks, 1);
        assert_eq!(stats.planner_floor_clamps, 0);
        set.shutdown();
    }

    #[test]
    fn cluster_config_from_toml() {
        use crate::config::RunConfig;
        // no [cluster] section -> single-coordinator deployment
        let doc = TomlDoc::parse("[server]\nworkers = 2\n").unwrap();
        let base = ServerConfig::from_toml(&doc).unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).unwrap().is_none());
        // homogeneous: every replica inherits the [server] shape
        let doc = TomlDoc::parse(
            "[server]\nmode = \"continuous\"\nslot_budget = 6\n[cluster]\nreplicas = 3\n",
        )
        .unwrap();
        let base = ServerConfig::from_toml(&doc).unwrap();
        let cfg = ClusterConfig::from_toml(&doc, &base).unwrap().unwrap();
        assert_eq!(cfg.replicas.len(), 3);
        assert!(cfg.replicas.iter().all(|r| r.slot_budget == 6));
        assert_eq!(cfg.route, RoutePolicy::PlanCost);
        // heterogeneous overrides + explicit route
        let doc = TomlDoc::parse(
            "[server]\nmode = \"continuous\"\nslot_budget = 8\n\
             [cluster]\nreplicas = 2\nroute = \"round-robin\"\nroute_seed = 7\n\
             [cluster.replica.1]\nslot_budget = 2\n",
        )
        .unwrap();
        let base = ServerConfig::from_toml(&doc).unwrap();
        let cfg = ClusterConfig::from_toml(&doc, &base).unwrap().unwrap();
        assert_eq!(cfg.route, RoutePolicy::RoundRobin);
        assert_eq!(cfg.route_seed, 7);
        assert_eq!(cfg.replicas[0].slot_budget, 8);
        assert_eq!(cfg.replicas[1].slot_budget, 2);
        // errors: zero replicas, bad route, orphan/out-of-range overrides
        let base = ServerConfig::default();
        let doc = TomlDoc::parse("[cluster]\nreplicas = 0\n").unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).is_err());
        let doc = TomlDoc::parse("[cluster]\nroute = \"bogus\"\n").unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).is_err());
        let doc =
            TomlDoc::parse("[cluster]\nreplicas = 2\n[cluster.replica.5]\nworkers = 2\n").unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).is_err());
        let doc = TomlDoc::parse("[cluster.replica.0]\nworkers = 2\n").unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).is_err());
        // an invalid per-replica shape is caught at parse time
        let doc =
            TomlDoc::parse(
                "[server]\nmode = \"continuous\"\n[cluster]\nreplicas = 1\n\
                 [cluster.replica.0]\nslot_budget = 1\n",
            )
            .unwrap();
        let base = ServerConfig::from_toml(&doc).unwrap();
        assert!(ClusterConfig::from_toml(&doc, &base).is_err());
        // the full RunConfig surface carries the section too
        let run = RunConfig::from_str(
            "[server]\nmode = \"continuous\"\n[cluster]\nreplicas = 2\n",
        )
        .unwrap();
        assert_eq!(run.cluster.as_ref().map(|c| c.replicas.len()), Some(2));
    }

    #[test]
    fn two_replica_cluster_serves_and_merges_stats() {
        let set = ReplicaSet::start(
            engine(),
            ClusterConfig::homogeneous(2, continuous(4)),
        )
        .unwrap();
        let reqs: Vec<GenerationRequest> = (0..6)
            .map(|i| {
                GenerationRequest::new(format!("p{i}"))
                    .steps(5)
                    .scheduler(SchedulerKind::Ddim)
                    .selective(WindowSpec::last(if i % 2 == 0 { 0.5 } else { 0.0 }))
                    .seed(i as u64)
                    .decode(false)
            })
            .collect();
        let tickets: Vec<(Ticket, PlacementTrace)> = reqs
            .iter()
            .map(|r| set.submit_traced(r.clone(), QosMeta::default()).expect("submit"))
            .collect();
        for (i, (t, trace)) in tickets.into_iter().enumerate() {
            let out = t.wait().expect("complete");
            assert!(out.latent.iter().all(|v| v.is_finite()), "sample {i}");
            assert_eq!(trace.history().len(), 1, "no requeues expected");
        }
        let stats = set.stats();
        assert_eq!(stats.submitted, 6);
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.requeued, 0);
        assert_eq!(stats.ejected, 0);
        assert_eq!(stats.queue_depth, 0, "everything drained");
        assert_eq!(stats.outstanding_evals, 0);
        assert_eq!(stats.healthy_replicas, 2);
        assert_eq!(stats.replicas.len(), 2);
        // the per-replica breakdown sums to the routed total
        assert_eq!(stats.replicas.iter().map(|r| r.routed).sum::<u64>(), 6);
        assert_eq!(
            stats.replicas.iter().map(|r| r.coordinator.completed).sum::<u64>(),
            6
        );
        assert!(stats.latency_ms_mean > 0.0);
        set.shutdown();
    }

    #[test]
    fn plan_cost_routing_balances_by_compiled_cost() {
        // submit a burst of full-CFG requests to an idle 2-replica
        // cluster: least-outstanding-evals must use both replicas (a
        // single replica would accumulate all the load)
        let set = ReplicaSet::start(
            engine(),
            ClusterConfig::homogeneous(2, continuous(2)),
        )
        .unwrap();
        let tickets: Vec<_> = (0..8)
            .map(|i| {
                let r = GenerationRequest::new(format!("b{i}"))
                    .steps(8)
                    .scheduler(SchedulerKind::Ddim)
                    .seed(i as u64)
                    .decode(false);
                set.submit_traced(r, QosMeta::default()).expect("submit")
            })
            .collect();
        let placements: Vec<usize> =
            tickets.iter().map(|(_, tr)| tr.history()[0]).collect();
        for (t, _) in tickets {
            t.wait().expect("complete");
        }
        assert!(
            placements.iter().any(|&p| p == 0) && placements.iter().any(|&p| p == 1),
            "plan-cost routing left a replica idle: {placements:?}"
        );
        set.shutdown();
    }

    #[test]
    fn kill_requeues_onto_survivor() {
        let set = ReplicaSet::start(
            engine(),
            ClusterConfig::homogeneous(2, continuous(2)),
        )
        .unwrap();
        // enough work that replica 0 has a queue when it dies
        let tickets: Vec<_> = (0..10)
            .map(|i| {
                let r = GenerationRequest::new(format!("k{i}"))
                    .steps(10)
                    .scheduler(SchedulerKind::Ddim)
                    .seed(i as u64)
                    .decode(false);
                set.submit_traced(r, QosMeta::default()).expect("submit")
            })
            .collect();
        set.kill(0).expect("kill");
        set.kill(0).expect("idempotent");
        for (i, (t, _)) in tickets.into_iter().enumerate() {
            let out = t.wait().unwrap_or_else(|e| panic!("request {i} lost: {e}"));
            assert!(out.latent.iter().all(|v| v.is_finite()));
        }
        let stats = set.stats();
        assert_eq!(stats.completed, 10, "killing a replica must lose no requests");
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.ejected, 1);
        assert_eq!(stats.healthy_replicas, 1);
        // anything replica 0 shed on death moved to replica 1
        let r0 = &stats.replicas[0];
        assert_eq!(stats.requeued, r0.coordinator.drain_shed);
        set.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let set = ReplicaSet::start(engine(), ClusterConfig::default()).unwrap();
        set.shutdown();
        let r = GenerationRequest::new("late").steps(2).decode(false);
        assert!(set.submit(r).is_err());
    }
}

