//! Request routing across engine replicas.
//!
//! The unit of load is **plan-compiled UNet evals** — since the plan IR
//! (DESIGN.md §10) every request carries `plan.total_unet_evals()`
//! *before* a single step runs, so the router can weigh a 50%-optimized
//! schedule as half the load of a full-CFG one instead of counting
//! requests. The router itself is unit-agnostic: when the fleet carries
//! calibrated cost tables (DESIGN.md §15) the cluster hands it job loads
//! in measured *microseconds* and weights scaled by each replica's
//! measured speed — same comparisons, a truer denominator. Two policies:
//!
//! * [`RoutePolicy::PlanCost`] (default) — weighted
//!   least-outstanding-evals with power-of-two-choices: sample two
//!   distinct eligible replicas (deterministic in-crate RNG), place on
//!   the one with the lower `outstanding_evals / capacity_weight`. The
//!   weight models heterogeneous hardware (a slot-budget-8 replica
//!   absorbs 4× the evals of a slot-budget-2 one at equal relative
//!   load); the two-choice sample keeps the policy O(1) per request and
//!   avoids the thundering-herd on a single least-loaded replica.
//! * [`RoutePolicy::RoundRobin`] — the replica-blind baseline the bench
//!   (`benches/cluster_scaling.rs`) measures the win against.
//!
//! The router is deliberately a pure, single-threaded object (the
//! [`crate::cluster::ReplicaSet`] serializes placements behind a mutex):
//! given the same seed and the same sequence of `(loads, place)` calls it
//! reproduces the same placements exactly, which is what makes cluster
//! traces replayable and the routing bench deterministic.

use crate::error::{Error, Result};
use crate::rng::Rng;

/// How the cluster places admitted requests onto replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicy {
    /// Weighted least-outstanding-evals with power-of-two-choices, the
    /// plan-cost-aware default.
    #[default]
    PlanCost,
    /// Replica-blind rotation (baseline).
    RoundRobin,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "plan-cost" | "plan_cost" | "least-evals" | "least_evals" => Ok(RoutePolicy::PlanCost),
            "round-robin" | "round_robin" | "rr" => Ok(RoutePolicy::RoundRobin),
            other => Err(Error::Config(format!("unknown route policy {other:?}"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::PlanCost => "plan-cost",
            RoutePolicy::RoundRobin => "round-robin",
        }
    }
}

/// Deterministic replica chooser. `weights[i]` is replica `i`'s capacity
/// weight (UNet slots it advances per unit time — see
/// [`crate::cluster::ReplicaSpec::capacity_weight`]); `loads[i]` at
/// placement time is the replica's outstanding plan-compiled evals, or
/// `None` when the replica is ineligible (unhealthy, or on the request's
/// excluded list after a requeue).
pub struct Router {
    policy: RoutePolicy,
    weights: Vec<f64>,
    rng: Rng,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy, weights: Vec<f64>, seed: u64) -> Result<Router> {
        if weights.is_empty() {
            return Err(Error::Config("router needs at least one replica".into()));
        }
        if weights.iter().any(|w| !w.is_finite() || *w <= 0.0) {
            return Err(Error::Config("replica capacity weights must be positive".into()));
        }
        Ok(Router {
            policy,
            weights,
            rng: Rng::for_stream(seed, 0x524F5554), // "ROUT"
            rr_next: 0,
        })
    }

    pub fn policy(&self) -> RoutePolicy {
        self.policy
    }

    pub fn replicas(&self) -> usize {
        self.weights.len()
    }

    /// Pick a replica for one admitted request. Returns `None` when no
    /// replica is eligible (all unhealthy / excluded).
    pub fn place(&mut self, loads: &[Option<u64>]) -> Option<usize> {
        assert_eq!(loads.len(), self.weights.len(), "load vector shape");
        let eligible: Vec<usize> = (0..loads.len()).filter(|&i| loads[i].is_some()).collect();
        if eligible.is_empty() {
            return None;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                // rotate over *all* slots so the cadence is stable as
                // replicas leave/rejoin, skipping ineligible ones
                for _ in 0..loads.len() {
                    let i = self.rr_next % loads.len();
                    self.rr_next = (self.rr_next + 1) % loads.len();
                    if loads[i].is_some() {
                        return Some(i);
                    }
                }
                unreachable!("eligible set is non-empty");
            }
            RoutePolicy::PlanCost => {
                let norm = |i: usize| loads[i].unwrap() as f64 / self.weights[i];
                if eligible.len() <= 2 {
                    // trivially compare the whole set; ties go to the
                    // lower index so placement stays deterministic
                    return eligible.iter().copied().min_by(|&a, &b| {
                        norm(a).partial_cmp(&norm(b)).expect("finite loads").then(a.cmp(&b))
                    });
                }
                // power of two choices among the eligible replicas
                let a = eligible[self.rng.next_below(eligible.len() as u64) as usize];
                let b = loop {
                    let c = eligible[self.rng.next_below(eligible.len() as u64) as usize];
                    if c != a {
                        break c;
                    }
                };
                let (la, lb) = (norm(a), norm(b));
                if la < lb || (la == lb && a < b) {
                    Some(a)
                } else {
                    Some(b)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parse_round_trips() {
        assert_eq!(RoutePolicy::parse("plan-cost").unwrap(), RoutePolicy::PlanCost);
        assert_eq!(RoutePolicy::parse("least_evals").unwrap(), RoutePolicy::PlanCost);
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("round-robin").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::parse("bogus").is_err());
        assert_eq!(RoutePolicy::PlanCost.name(), "plan-cost");
        assert_eq!(RoutePolicy::RoundRobin.name(), "round-robin");
        assert_eq!(RoutePolicy::default(), RoutePolicy::PlanCost);
    }

    #[test]
    fn router_validates_weights() {
        assert!(Router::new(RoutePolicy::PlanCost, vec![], 0).is_err());
        assert!(Router::new(RoutePolicy::PlanCost, vec![1.0, 0.0], 0).is_err());
        assert!(Router::new(RoutePolicy::PlanCost, vec![1.0, f64::NAN], 0).is_err());
        assert!(Router::new(RoutePolicy::PlanCost, vec![8.0, 2.0], 0).is_ok());
    }

    #[test]
    fn round_robin_rotates_and_skips_ineligible() {
        let mut r = Router::new(RoutePolicy::RoundRobin, vec![1.0; 3], 7).unwrap();
        let all = [Some(0u64), Some(0), Some(0)];
        assert_eq!(r.place(&all), Some(0));
        assert_eq!(r.place(&all), Some(1));
        assert_eq!(r.place(&all), Some(2));
        assert_eq!(r.place(&all), Some(0));
        // replica 1 ejected: the rotation skips it without stalling
        let holed = [Some(0u64), None, Some(0)];
        assert_eq!(r.place(&holed), Some(2));
        assert_eq!(r.place(&holed), Some(0));
        assert_eq!(r.place(&[None, None, None]), None);
    }

    #[test]
    fn plan_cost_prefers_lower_normalized_load() {
        let mut r = Router::new(RoutePolicy::PlanCost, vec![8.0, 2.0], 1).unwrap();
        // two replicas -> both compared directly. 40/8 = 5 < 30/2 = 15:
        // absolute evals lie, normalized load doesn't
        assert_eq!(r.place(&[Some(40), Some(30)]), Some(0));
        // equal normalized load ties to the lower index
        assert_eq!(r.place(&[Some(8), Some(2)]), Some(0));
        // the weak replica wins only when genuinely less loaded
        assert_eq!(r.place(&[Some(80), Some(2)]), Some(1));
        // exclusion forces the other
        assert_eq!(r.place(&[None, Some(999)]), Some(1));
    }

    #[test]
    fn plan_cost_two_choices_is_deterministic_and_load_seeking() {
        // 4 replicas: same seed -> same placement stream
        let mk = || Router::new(RoutePolicy::PlanCost, vec![1.0; 4], 42).unwrap();
        let loads = [Some(10u64), Some(0), Some(7), Some(3)];
        let a: Vec<_> = {
            let mut r = mk();
            (0..32).map(|_| r.place(&loads).unwrap()).collect()
        };
        let b: Vec<_> = {
            let mut r = mk();
            (0..32).map(|_| r.place(&loads).unwrap()).collect()
        };
        assert_eq!(a, b);
        // the most loaded replica is never chosen by a two-choice sample
        // that includes any alternative, so it appears least often;
        // replica 1 (idle) wins every sample it appears in
        let count = |v: &[usize], i: usize| v.iter().filter(|&&x| x == i).count();
        assert!(count(&a, 1) > count(&a, 0), "idle replica must attract placements: {a:?}");
    }
}
