//! Recursive-descent JSON parser with line/column error reporting.

use std::collections::BTreeMap;
use std::fmt;

use super::value::{Number, Value};

/// Parse failure with position info.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub msg: String,
    pub line: usize,
    pub col: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} at line {} col {}", self.msg, self.line, self.col)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

const MAX_DEPTH: usize = 128;

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        Err(ParseError { msg: msg.into(), line, col })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!(
                "expected {:?}, found {:?}",
                b as char,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, ParseError> {
        if depth > MAX_DEPTH {
            return self.err("nesting too deep");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected character {:?}", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("invalid literal (expected {word})"))
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                other => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err(format!(
                        "expected ',' or '}}' in object, found {:?}",
                        other.map(|c| c as char)
                    ));
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(out)),
                other => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err(format!(
                        "expected ',' or ']' in array, found {:?}",
                        other.map(|c| c as char)
                    ));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xDC00).contains(&cp) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired surrogate");
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return self.err("invalid low surrogate");
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(char::from_u32(c).ok_or(()).or_else(
                                |_| self.err::<char>("invalid code point"),
                            )?);
                        } else {
                            match char::from_u32(cp) {
                                Some(c) => out.push(c),
                                None => return self.err("invalid code point"),
                            }
                        }
                    }
                    other => {
                        return self.err(format!(
                            "invalid escape {:?}",
                            other.map(|c| c as char)
                        ))
                    }
                },
                Some(b) if b < 0x20 => return self.err("control character in string"),
                Some(b) => {
                    // re-assemble UTF-8 from raw bytes
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = match b {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return self.err("invalid UTF-8"),
                        };
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return self.err("truncated UTF-8");
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return self.err("invalid UTF-8"),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = match self.bump() {
                Some(b) => b,
                None => return self.err("truncated \\u escape"),
            };
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a' + 10) as u32,
                b'A'..=b'F' => (b - b'A' + 10) as u32,
                _ => return self.err("invalid hex digit"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Num(Number::Int(i)));
            }
        }
        match text.parse::<f64>() {
            Ok(f) => Ok(Value::Num(Number::Float(f))),
            Err(_) => self.err(format!("invalid number {text:?}")),
        }
    }
}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(s: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing characters after document");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-42").unwrap(), Value::int(-42));
        assert_eq!(parse("2.5e3").unwrap(), Value::float(2500.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::str("hi"));
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a":[{"b":[1,[2,[3]]]}]}"#).unwrap();
        let b = v.get("a").unwrap().as_arr().unwrap()[0].get("b").unwrap();
        assert_eq!(b.as_arr().unwrap()[0].as_i64(), Some(1));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::str("Aé"));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::str("😀"));
    }

    #[test]
    fn raw_utf8_passthrough() {
        assert_eq!(parse("\"héllo 😀\"").unwrap(), Value::str("héllo 😀"));
    }

    #[test]
    fn error_positions() {
        let e = parse("{\n  \"a\": ?\n}").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(e.col >= 8, "{e}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("1 2").is_err());
        assert!(parse("{} []").is_err());
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "01x", "[,]",
                    "{\"a\":}", "\"\\q\"", "nul"] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn deep_nesting_bounded() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(64) + &"]".repeat(64);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn big_ints_preserved() {
        assert_eq!(parse("9007199254740993").unwrap().as_i64(), Some(9007199254740993));
    }
}
