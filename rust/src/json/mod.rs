//! Minimal JSON substrate (no `serde` in the offline registry snapshot).
//!
//! Used for the artifact manifests written by `python/compile/aot.py`,
//! the JSON-lines wire protocol of the TCP front-end, and run manifests
//! written next to benchmark outputs.

mod parse;
mod value;

pub use parse::{parse, ParseError};
pub use value::{Number, Value};

use crate::error::{Error, Result};

/// Parse a JSON document from a string, mapping errors into [`Error`].
pub fn from_str(s: &str) -> Result<Value> {
    parse(s).map_err(|e| Error::Json(e.to_string()))
}

/// Read + parse a JSON file.
pub fn from_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::io(format!("reading {}", path.display()), e))?;
    from_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_nested() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = from_str(src).unwrap();
        let out = v.to_string();
        let v2 = from_str(&out).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn file_error_has_path() {
        let err = from_file(std::path::Path::new("/nonexistent/x.json")).unwrap_err();
        assert!(err.to_string().contains("/nonexistent/x.json"));
    }
}
