//! JSON value model + serializer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON number. Stored as f64 with an integer fast-path so manifest
/// shape entries round-trip exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Number {
    Int(i64),
    Float(f64),
}

impl Number {
    pub fn as_f64(self) -> f64 {
        match self {
            Number::Int(i) => i as f64,
            Number::Float(f) => f,
        }
    }

    pub fn as_i64(self) -> Option<i64> {
        match self {
            Number::Int(i) => Some(i),
            Number::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(f as i64),
            _ => None,
        }
    }
}

/// A JSON document node. Object keys are sorted (BTreeMap) so serialized
/// output is canonical — handy for hashing run manifests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(Number),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- constructors -------------------------------------------------
    pub fn int(i: i64) -> Value {
        Value::Num(Number::Int(i))
    }

    pub fn float(f: f64) -> Value {
        Value::Num(Number::Float(f))
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn obj() -> Value {
        Value::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if self is not an object.
    pub fn with(mut self, key: &str, v: impl Into<Value>) -> Value {
        match &mut self {
            Value::Obj(m) => {
                m.insert(key.to_string(), v.into());
            }
            _ => panic!("with() on non-object"),
        }
        self
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(n.as_f64()),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) => n.as_i64(),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Typed lookup with a contextual error, for manifest parsing.
    pub fn req<'a>(&'a self, key: &str) -> Result<&'a Value, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Value {
        Value::int(i)
    }
}

impl From<usize> for Value {
    fn from(i: usize) -> Value {
        Value::int(i as i64)
    }
}

impl From<f64> for Value {
    fn from(f: f64) -> Value {
        Value::float(f)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::str(s)
    }
}

impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::Str(s)
    }
}

impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut buf = String::new();
        self.write_into(&mut buf);
        f.write_str(&buf)
    }
}

impl Value {
    fn write_into(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(Number::Int(i)) => out.push_str(&i.to_string()),
            Value::Num(Number::Float(x)) => {
                if x.is_finite() {
                    // ensure a float marker so round-trips stay floats
                    let s = format!("{x}");
                    out.push_str(&s);
                    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Value::Str(s) => escape_into(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_access() {
        let v = Value::obj()
            .with("n", 42i64)
            .with("f", 2.5)
            .with("s", "hi")
            .with("b", true)
            .with("a", vec![1i64, 2, 3]);
        assert_eq!(v.get("n").unwrap().as_i64(), Some(42));
        assert_eq!(v.get("f").unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn serialization_canonical_key_order() {
        let v = Value::obj().with("z", 1i64).with("a", 2i64);
        assert_eq!(v.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn string_escaping() {
        let v = Value::str("a\"b\\c\nd\te\u{1}");
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\te\\u0001\"");
    }

    #[test]
    fn float_round_trip_marker() {
        assert_eq!(Value::float(3.0).to_string(), "3.0");
        assert_eq!(Value::float(0.25).to_string(), "0.25");
        assert_eq!(Value::int(3).to_string(), "3");
    }

    #[test]
    fn int_float_bridging() {
        assert_eq!(Number::Float(5.0).as_i64(), Some(5));
        assert_eq!(Number::Float(5.5).as_i64(), None);
        assert_eq!(Number::Int(-2).as_f64(), -2.0);
    }
}
