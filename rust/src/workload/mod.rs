//! Workload generation + trace replay — the serving-evaluation substrate.
//!
//! The paper measures single-stream latency; a serving system also cares
//! how the saving translates under load (queueing amplifies per-image
//! savings into latency/throughput headroom). This module provides
//! deterministic arrival processes (Poisson / uniform / bursty), trace
//! synthesis over the Table-2 prompt corpus, and a replay driver that
//! submits against a [`crate::coordinator::Coordinator`]
//! with per-request SLO accounting. The `slo_serving` bench builds its
//! load-vs-latency curves on top.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::Coordinator;
use crate::engine::GenerationRequest;
use crate::error::Result;
use crate::guidance::WindowSpec;
use crate::metrics::SampleStats;
use crate::prompts;
use crate::rng::Rng;
use crate::scheduler::SchedulerKind;

/// Inter-arrival process for synthetic request streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Deterministic uniform spacing at `rate_per_s`.
    Uniform { rate_per_s: f64 },
    /// On/off bursts: Poisson at `burst_rate_per_s` for `on_ms`, idle for
    /// `off_ms`, repeating.
    Bursty { burst_rate_per_s: f64, on_ms: u64, off_ms: u64 },
}

impl ArrivalProcess {
    /// Generate `n` arrival offsets (milliseconds from start), sorted.
    pub fn arrivals(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::for_stream(seed, 0x41525256); // "ARRV"
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => {
                assert!(rate_per_s > 0.0);
                let mean_gap_ms = 1e3 / rate_per_s;
                for _ in 0..n {
                    // exponential inter-arrival via inverse CDF
                    let u = 1.0 - rng.next_f64(); // (0, 1]
                    t += -mean_gap_ms * u.ln();
                    out.push(t);
                }
            }
            ArrivalProcess::Uniform { rate_per_s } => {
                assert!(rate_per_s > 0.0);
                let gap = 1e3 / rate_per_s;
                for i in 0..n {
                    out.push(gap * i as f64);
                }
            }
            ArrivalProcess::Bursty { burst_rate_per_s, on_ms, off_ms } => {
                assert!(burst_rate_per_s > 0.0);
                let mean_gap_ms = 1e3 / burst_rate_per_s;
                let period = (on_ms + off_ms) as f64;
                for _ in 0..n {
                    let u = 1.0 - rng.next_f64();
                    t += -mean_gap_ms * u.ln();
                    // skip the off window: fold the raw timeline onto
                    // on-periods only
                    let cycle = (t / on_ms as f64).floor();
                    out.push(t + cycle * off_ms as f64 - if cycle > 0.0 { 0.0 } else { 0.0 });
                    let _ = period;
                }
            }
        }
        out
    }
}

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival offset from trace start, milliseconds.
    pub at_ms: f64,
    pub request: GenerationRequest,
}

/// Trace synthesis parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrivals: ArrivalProcess,
    pub num_requests: usize,
    pub steps: usize,
    pub scheduler: SchedulerKind,
    /// Selective-guidance window applied to all requests.
    pub window: WindowSpec,
    pub guidance_scale: f32,
    pub decode: bool,
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { rate_per_s: 4.0 },
            num_requests: 32,
            steps: 50,
            scheduler: SchedulerKind::Pndm,
            window: WindowSpec::none(),
            guidance_scale: 7.5,
            decode: false,
            seed: 0,
        }
    }
}

impl WorkloadSpec {
    /// Synthesize a deterministic trace over the Table-2 corpus.
    pub fn synthesize(&self) -> Vec<TraceEntry> {
        let arrivals = self.arrivals.arrivals(self.num_requests, self.seed);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, at_ms)| {
                let prompt = prompts::TABLE2[i % prompts::TABLE2.len()];
                let request = GenerationRequest::new(prompt)
                    .steps(self.steps)
                    .scheduler(self.scheduler)
                    .guidance_scale(self.guidance_scale)
                    .selective(self.window)
                    .seed(self.seed.wrapping_add(i as u64))
                    .decode(self.decode);
                TraceEntry { at_ms, request }
            })
            .collect()
    }
}

/// Result of replaying one trace.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// End-to-end latency per request (submit -> response), ms, in
    /// completion order.
    pub latencies_ms: Vec<f64>,
    /// Wall time of the whole replay, seconds.
    pub wall_s: f64,
    /// Achieved throughput, images/s.
    pub throughput: f64,
    /// Requests that failed.
    pub failures: usize,
}

impl ReplayReport {
    pub fn latency_stats(&self) -> SampleStats {
        SampleStats::from(&self.latencies_ms)
    }

    /// Fraction of requests meeting a latency SLO.
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().filter(|&&l| l <= slo_ms).count() as f64
            / self.latencies_ms.len() as f64
    }
}

/// Replay a trace against a coordinator, honoring arrival times
/// (open-loop). Blocks until every request completes.
pub fn replay(coordinator: &Arc<Coordinator>, trace: &[TraceEntry]) -> Result<ReplayReport> {
    let start = Instant::now();
    let mut pending = Vec::with_capacity(trace.len());
    for entry in trace {
        let target = Duration::from_secs_f64(entry.at_ms.max(0.0) / 1e3);
        let now = start.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        pending.push(coordinator.submit(entry.request.clone())?);
    }
    let mut latencies = Vec::with_capacity(pending.len());
    let mut failures = 0usize;
    for ticket in pending {
        // latency is stamped by the worker at completion, so consuming
        // the tickets late (after the open-loop submission ends) does not
        // inflate the numbers
        match ticket.wait_timed() {
            Ok((_, latency)) => latencies.push(latency.as_secs_f64() * 1e3),
            Err(_) => failures += 1,
        }
    }
    let wall_s = start.elapsed().as_secs_f64();
    let throughput = latencies.len() as f64 / wall_s;
    Ok(ReplayReport { latencies_ms: latencies, wall_s, throughput, failures })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn poisson_arrivals_sorted_and_rate_correct() {
        let ap = ArrivalProcess::Poisson { rate_per_s: 100.0 };
        let arr = ap.arrivals(2000, 1);
        assert_eq!(arr.len(), 2000);
        assert!(arr.windows(2).all(|w| w[1] >= w[0]));
        // mean gap ~ 10ms within 10%
        let mean_gap = arr.last().unwrap() / 2000.0;
        assert!((mean_gap - 10.0).abs() < 1.0, "mean gap {mean_gap}ms");
    }

    #[test]
    fn uniform_arrivals_exact() {
        let ap = ArrivalProcess::Uniform { rate_per_s: 10.0 };
        let arr = ap.arrivals(5, 0);
        assert_eq!(arr, vec![0.0, 100.0, 200.0, 300.0, 400.0]);
    }

    #[test]
    fn arrivals_deterministic_by_seed() {
        let ap = ArrivalProcess::Poisson { rate_per_s: 5.0 };
        assert_eq!(ap.arrivals(50, 7), ap.arrivals(50, 7));
        assert_ne!(ap.arrivals(50, 7), ap.arrivals(50, 8));
    }

    #[test]
    fn bursty_arrivals_monotone() {
        let ap = ArrivalProcess::Bursty { burst_rate_per_s: 50.0, on_ms: 100, off_ms: 400 };
        let arr = ap.arrivals(100, 3);
        assert!(arr.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn trace_synthesis_covers_corpus() {
        let spec = WorkloadSpec {
            num_requests: 70,
            window: WindowSpec::last(0.2),
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 70);
        // prompts cycle through Table 2
        assert_eq!(trace[0].request.prompt, prompts::TABLE2[0]);
        assert_eq!(trace[61].request.prompt, prompts::TABLE2[0]);
        // every request carries the spec's policy and a distinct seed
        assert!(trace.iter().all(|t| t.request.window == WindowSpec::last(0.2)));
        let mut seeds: Vec<u64> = trace.iter().map(|t| t.request.seed).collect();
        seeds.dedup();
        assert_eq!(seeds.len(), 70);
    }

    #[test]
    fn replay_report_slo_math() {
        let report = ReplayReport {
            latencies_ms: vec![10.0, 20.0, 30.0, 40.0],
            wall_s: 1.0,
            throughput: 4.0,
            failures: 0,
        };
        assert_eq!(report.slo_attainment(25.0), 0.5);
        assert_eq!(report.slo_attainment(100.0), 1.0);
        assert_eq!(report.slo_attainment(5.0), 0.0);
    }

    #[test]
    fn arrival_rates_scale_property() {
        forall("arrival rate scaling", 20, |g| {
            let rate = g.f64_in(1.0, 200.0);
            let ap = ArrivalProcess::Poisson { rate_per_s: rate };
            let n = 500;
            let arr = ap.arrivals(n, g.u64());
            let measured_rate = n as f64 / (arr.last().unwrap() / 1e3);
            assert!(
                (measured_rate - rate).abs() / rate < 0.25,
                "target {rate}/s, measured {measured_rate}/s"
            );
        });
    }
}
