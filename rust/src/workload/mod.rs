//! Workload generation + trace replay — the serving-evaluation substrate.
//!
//! The paper measures single-stream latency; a serving system also cares
//! how the saving translates under load (queueing amplifies per-image
//! savings into latency/throughput headroom). This module provides
//! deterministic arrival processes (Poisson / uniform / bursty), trace
//! synthesis over the Table-2 prompt corpus, and replay drivers that
//! submit against any [`crate::coordinator::Submit`] sink — a single
//! [`crate::coordinator::Coordinator`] or a [`ReplicaSet`] — with
//! per-request SLO accounting; [`replay_qos_cluster`] adds replica
//! failure injection ([`KillSpec`]). The `slo_serving` and
//! `cluster_scaling` benches build their curves on top.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::ReplicaSet;
use crate::coordinator::Submit;
use crate::engine::GenerationRequest;
use crate::error::{Error, Result};
use crate::guidance::{GuidanceSchedule, GuidanceStrategy};
use crate::metrics::SampleStats;
use crate::prompts;
use crate::qos::{Priority, QosMeta};
use crate::rng::Rng;
use crate::scheduler::SchedulerKind;

/// Inter-arrival process for synthetic request streams.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Poisson arrivals at `rate_per_s`.
    Poisson { rate_per_s: f64 },
    /// Deterministic uniform spacing at `rate_per_s`.
    Uniform { rate_per_s: f64 },
    /// On/off bursts: Poisson at `burst_rate_per_s` for `on_ms`, idle for
    /// `off_ms`, repeating.
    Bursty { burst_rate_per_s: f64, on_ms: u64, off_ms: u64 },
}

impl ArrivalProcess {
    /// Generate `n` arrival offsets (milliseconds from start), sorted.
    pub fn arrivals(&self, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::for_stream(seed, 0x41525256); // "ARRV"
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_per_s } => {
                assert!(rate_per_s > 0.0);
                let mean_gap_ms = 1e3 / rate_per_s;
                for _ in 0..n {
                    // exponential inter-arrival via inverse CDF
                    let u = 1.0 - rng.next_f64(); // (0, 1]
                    t += -mean_gap_ms * u.ln();
                    out.push(t);
                }
            }
            ArrivalProcess::Uniform { rate_per_s } => {
                assert!(rate_per_s > 0.0);
                let gap = 1e3 / rate_per_s;
                for i in 0..n {
                    out.push(gap * i as f64);
                }
            }
            ArrivalProcess::Bursty { burst_rate_per_s, on_ms, off_ms } => {
                assert!(burst_rate_per_s > 0.0);
                let mean_gap_ms = 1e3 / burst_rate_per_s;
                let period = (on_ms + off_ms) as f64;
                for _ in 0..n {
                    let u = 1.0 - rng.next_f64();
                    t += -mean_gap_ms * u.ln();
                    // skip the off window: fold the raw timeline onto
                    // on-periods only
                    let cycle = (t / on_ms as f64).floor();
                    out.push(t + cycle * off_ms as f64 - if cycle > 0.0 { 0.0 } else { 0.0 });
                    let _ = period;
                }
            }
        }
        out
    }
}

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival offset from trace start, milliseconds.
    pub at_ms: f64,
    pub request: GenerationRequest,
    /// Serving metadata (deadline, priority) for the QoS replay path.
    pub meta: QosMeta,
}

/// Failure injection: kill (eject) a cluster replica mid-replay. Only
/// meaningful for the cluster replay driver ([`replay_qos_cluster`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KillSpec {
    /// Offset from replay start, milliseconds.
    pub at_ms: f64,
    /// Replica id to eject.
    pub replica: usize,
}

/// Zipf-skewed request popularity (cache/dedup evaluation): request
/// ranks are drawn with P(rank k) ∝ 1/k^s over a fixed catalog, and
/// **both** the prompt and the per-request seed derive from the sampled
/// rank — so two draws of the same rank are exact-key duplicates (the
/// request cache / dedup tier can serve one from the other), while
/// distinct ranks never collide (their seeds differ even when the
/// prompt corpus wraps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ZipfPrompts {
    /// Skew exponent `s` (0 = uniform over the catalog; web-like
    /// popularity is typically 0.7–1.2).
    pub skew: f64,
    /// Catalog size: ranks `0..catalog`.
    pub catalog: usize,
}

impl ZipfPrompts {
    pub fn validate(&self) -> Result<()> {
        if !(self.skew.is_finite() && self.skew >= 0.0) {
            return Err(Error::Config(format!(
                "zipf skew {} must be finite and >= 0",
                self.skew
            )));
        }
        if self.catalog == 0 {
            return Err(Error::Config("zipf catalog must be >= 1".into()));
        }
        Ok(())
    }

    /// Draw `n` ranks by inverse CDF over the truncated Zipf weights —
    /// deterministic in `seed`, independent of the arrival stream.
    pub fn ranks(&self, n: usize, seed: u64) -> Vec<usize> {
        let catalog = self.catalog.max(1);
        let mut rng = Rng::for_stream(seed, 0x5A495046); // "ZIPF"
        let mut cum = Vec::with_capacity(catalog);
        let mut total = 0.0f64;
        for k in 0..catalog {
            total += 1.0 / ((k + 1) as f64).powf(self.skew);
            cum.push(total);
        }
        (0..n)
            .map(|_| {
                let u = rng.next_f64() * total;
                cum.partition_point(|&c| c < u).min(catalog - 1)
            })
            .collect()
    }
}

/// Trace synthesis parameters.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub arrivals: ArrivalProcess,
    pub num_requests: usize,
    pub steps: usize,
    /// Mixed-class traffic: when non-empty, request `i` runs
    /// `steps_choices[i % len]` steps instead of the uniform `steps`.
    /// The fixed batcher splits these into separate lock-step classes;
    /// the continuous batcher cohorts them together (DESIGN.md §9), so
    /// this is the knob that exercises the difference under replay.
    pub steps_choices: Vec<usize>,
    pub scheduler: SchedulerKind,
    /// Guidance schedule applied to all requests (windows, segments,
    /// limited intervals, cadences).
    pub schedule: GuidanceSchedule,
    /// Guidance strategy for the optimized steps (reuse lattice).
    pub strategy: GuidanceStrategy,
    pub guidance_scale: f32,
    pub decode: bool,
    pub seed: u64,
    /// Deadline attached to every request (None = best effort).
    pub deadline_ms: Option<f64>,
    /// Priority class attached to every request.
    pub priority: Priority,
    /// Replica-failure injection: replicas killed mid-replay. Kill
    /// events are not trace entries (they target the cluster, not a
    /// request), so [`WorkloadSpec::synthesize`] leaves them out —
    /// cluster replays pass them to [`replay_qos_cluster`] alongside
    /// the trace.
    pub kills: Vec<KillSpec>,
    /// Zipf-skewed popularity: when set, prompt *and* per-request seed
    /// derive from a sampled rank (repeats become exact-key duplicates
    /// — the workload the amortization tiers are measured on). `None`
    /// keeps the classic round-robin corpus walk.
    pub zipf: Option<ZipfPrompts>,
    /// img2img traffic: when set, every request carries a synthetic
    /// init latent at this strength, truncating the denoising loop to
    /// `round(steps * strength)` executed iterations (DESIGN.md §14).
    /// `(0, 1]`; `None` keeps pure text2img.
    pub strength: Option<f64>,
    /// Variation fan-out: each trace arrival expands into this many
    /// requests differing only by seed and sharing ONE compiled
    /// guidance plan ([`GenerationRequest::variations`]). The trace
    /// grows to `num_requests * variations` entries, all variations of
    /// an arrival landing at the same offset. 1 = no fan-out.
    pub variations: usize,
    /// Frontier plan-search eligibility (DESIGN.md §16). `false` marks
    /// every trace entry opted out ([`QosMeta::planner_opt_out`]): under
    /// pressure those requests degrade via the legacy analytic actuator
    /// instead of the sealed Pareto frontier. Default `true`.
    pub planner: bool,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            arrivals: ArrivalProcess::Poisson { rate_per_s: 4.0 },
            num_requests: 32,
            steps: 50,
            steps_choices: Vec::new(),
            scheduler: SchedulerKind::Pndm,
            schedule: GuidanceSchedule::none(),
            strategy: GuidanceStrategy::CondOnly,
            guidance_scale: 7.5,
            decode: false,
            seed: 0,
            deadline_ms: None,
            priority: Priority::Standard,
            kills: Vec::new(),
            zipf: None,
            strength: None,
            variations: 1,
            planner: true,
        }
    }
}

impl WorkloadSpec {
    /// Set the base seed from a signed value — the same negative-seed
    /// validation as the TOML/wire/CLI surfaces, so a workload script
    /// can't wrap a typo'd `-1` into a valid-looking u64 seed.
    pub fn with_seed_i64(mut self, seed: i64) -> Result<WorkloadSpec> {
        self.seed = crate::config::seed_from_i64(seed).map_err(Error::Config)?;
        Ok(self)
    }

    /// Synthesize a deterministic trace over the Table-2 corpus.
    pub fn synthesize(&self) -> Vec<TraceEntry> {
        let arrivals = self.arrivals.arrivals(self.num_requests, self.seed);
        // popularity stream: request i carries identity rank(i) — with
        // Zipf popularity repeats are *exact* duplicates (same prompt,
        // seed and steps), without it identity is just the index
        let ranks = self.zipf.map(|z| z.ranks(self.num_requests, self.seed));
        // with_deadline_ms owns the clamp (MAX_DEADLINE_MS, non-finite)
        // so a hostile spec can't panic Duration construction
        let meta = QosMeta {
            priority: self.priority,
            planner_opt_out: !self.planner,
            ..self
                .deadline_ms
                .map(QosMeta::with_deadline_ms)
                .unwrap_or_default()
        };
        arrivals
            .into_iter()
            .enumerate()
            .flat_map(|(i, at_ms)| {
                let rank = ranks.as_ref().map_or(i, |r| r[i]);
                let prompt = prompts::TABLE2[rank % prompts::TABLE2.len()];
                let steps = if self.steps_choices.is_empty() {
                    self.steps
                } else {
                    self.steps_choices[rank % self.steps_choices.len()]
                };
                // variations fan out the *rank-spaced* base seed so two
                // arrivals' variation sets never interleave collisions
                let base_seed = self.seed.wrapping_add((rank as u64) * self.variations.max(1) as u64);
                let mut request = GenerationRequest::new(prompt)
                    .steps(steps)
                    .scheduler(self.scheduler)
                    .guidance_scale(self.guidance_scale)
                    .with_schedule(self.schedule.clone())
                    .strategy(self.strategy)
                    .seed(base_seed)
                    .decode(self.decode);
                if let Some(strength) = self.strength {
                    request = request.img2img(strength);
                }
                let group = if self.variations > 1 {
                    // errors only on n == 0 or an invalid request; an
                    // unplannable spec degrades to the unshared clone
                    // path and fails at submit with the real error
                    request
                        .variations(self.variations)
                        .unwrap_or_else(|_| vec![request; self.variations])
                } else {
                    vec![request]
                };
                group
                    .into_iter()
                    .map(move |request| TraceEntry { at_ms, request, meta })
            })
            .collect()
    }

    /// Build from the `[workload]` TOML section. Returns `Ok(None)` when
    /// the section is absent. Guidance policy (schedule / strategy /
    /// scheduler / steps / scale / seed) seeds from the resolved
    /// `[engine]`+`[guidance]` config so a deployment file describes it
    /// once; `[workload]` keys override the traffic shape on top.
    pub fn from_toml(
        doc: &crate::config::TomlDoc,
        engine: &crate::config::EngineConfig,
    ) -> Result<Option<WorkloadSpec>> {
        const S: &str = "workload";
        if doc.section(S).is_none() {
            return Ok(None);
        }
        let mut spec = WorkloadSpec {
            steps: engine.steps,
            scheduler: engine.scheduler,
            schedule: engine.schedule.clone(),
            strategy: engine.guidance_strategy,
            guidance_scale: engine.guidance_scale,
            seed: engine.seed,
            ..WorkloadSpec::default()
        };
        let bad = |m: &str| Error::Config(format!("workload {m}"));
        // ---- arrival process: kind + rate, burst knobs gated on kind
        let rate = match doc.get(S, "rate_per_s") {
            Some(v) => {
                let r = v.as_f64().ok_or_else(|| bad("rate_per_s must be number"))?;
                if !(r.is_finite() && r > 0.0) {
                    return Err(bad("rate_per_s must be > 0"));
                }
                Some(r)
            }
            None => None,
        };
        let on_ms = match doc.get(S, "on_ms") {
            Some(v) => Some(v.as_usize().ok_or_else(|| bad("on_ms must be int >= 0"))? as u64),
            None => None,
        };
        let off_ms = match doc.get(S, "off_ms") {
            Some(v) => Some(v.as_usize().ok_or_else(|| bad("off_ms must be int >= 0"))? as u64),
            None => None,
        };
        let kind = match doc.get(S, "arrival") {
            Some(v) => v.as_str().ok_or_else(|| bad("arrival must be string"))?,
            None => "poisson",
        };
        spec.arrivals = match kind.to_ascii_lowercase().as_str() {
            "poisson" | "uniform" => {
                // burst knobs without the bursty process are an operator
                // error, not a silent no-op (the orphan-knob rule)
                if on_ms.is_some() || off_ms.is_some() {
                    return Err(bad("on_ms/off_ms require arrival = \"bursty\""));
                }
                let rate_per_s = rate.unwrap_or(4.0);
                if kind.eq_ignore_ascii_case("poisson") {
                    ArrivalProcess::Poisson { rate_per_s }
                } else {
                    ArrivalProcess::Uniform { rate_per_s }
                }
            }
            "bursty" => {
                let on = on_ms.unwrap_or(100);
                if on == 0 {
                    return Err(bad("on_ms must be >= 1"));
                }
                ArrivalProcess::Bursty {
                    burst_rate_per_s: rate.unwrap_or(4.0),
                    on_ms: on,
                    off_ms: off_ms.unwrap_or(400),
                }
            }
            other => return Err(bad(&format!("unknown arrival process {other:?}"))),
        };
        // ---- trace shape
        if let Some(v) = doc.get(S, "requests") {
            spec.num_requests = v.as_usize().ok_or_else(|| bad("requests must be int"))?;
            if spec.num_requests == 0 {
                return Err(bad("requests must be >= 1"));
            }
        }
        if let Some(v) = doc.get(S, "steps") {
            spec.steps = v.as_usize().ok_or_else(|| bad("steps must be int"))?;
        }
        if let Some(v) = doc.get(S, "scheduler") {
            spec.scheduler = SchedulerKind::parse(
                v.as_str().ok_or_else(|| bad("scheduler must be string"))?,
            )?;
        }
        if let Some(v) = doc.get(S, "guidance_scale") {
            spec.guidance_scale =
                v.as_f64().ok_or_else(|| bad("guidance_scale must be number"))? as f32;
        }
        if let Some(v) = doc.get(S, "decode") {
            spec.decode = v.as_bool().ok_or_else(|| bad("decode must be bool"))?;
        }
        if let Some(v) = doc.get(S, "seed") {
            let raw = v.as_i64().ok_or_else(|| bad("seed must be int"))?;
            spec.seed = crate::config::seed_from_i64(raw).map_err(Error::Config)?;
        }
        // ---- QoS metadata
        if let Some(v) = doc.get(S, "deadline_ms") {
            let d = v.as_f64().ok_or_else(|| bad("deadline_ms must be number"))?;
            if !(d.is_finite() && d > 0.0) {
                return Err(bad("deadline_ms must be > 0"));
            }
            spec.deadline_ms = Some(d);
        }
        if let Some(v) = doc.get(S, "priority") {
            spec.priority =
                Priority::parse(v.as_str().ok_or_else(|| bad("priority must be string"))?)?;
        }
        // ---- the streaming-plane workloads: img2img + variations
        if let Some(v) = doc.get(S, "strength") {
            let s = v.as_f64().ok_or_else(|| bad("strength must be number"))?;
            if !(s.is_finite() && s > 0.0 && s <= 1.0) {
                return Err(bad(&format!("strength {s} outside (0, 1]")));
            }
            spec.strength = Some(s);
        }
        if let Some(v) = doc.get(S, "variations") {
            spec.variations =
                v.as_usize().ok_or_else(|| bad("variations must be a positive integer"))?;
            if spec.variations == 0 {
                return Err(bad("variations must be >= 1"));
            }
        }
        // ---- frontier plan-search eligibility (DESIGN.md §16)
        if let Some(v) = doc.get(S, "planner") {
            spec.planner = v.as_bool().ok_or_else(|| bad("planner must be bool"))?;
        }
        // ---- popularity skew (both-or-neither, like window knobs)
        let zipf_skew = match doc.get(S, "zipf_skew") {
            Some(v) => Some(v.as_f64().ok_or_else(|| bad("zipf_skew must be number"))?),
            None => None,
        };
        let zipf_catalog = match doc.get(S, "zipf_catalog") {
            Some(v) => Some(v.as_usize().ok_or_else(|| bad("zipf_catalog must be int"))?),
            None => None,
        };
        spec.zipf = match (zipf_skew, zipf_catalog) {
            (Some(skew), Some(catalog)) => {
                let z = ZipfPrompts { skew, catalog };
                z.validate()?;
                Some(z)
            }
            (None, None) => None,
            _ => return Err(bad("zipf_skew and zipf_catalog must be set together")),
        };
        Ok(Some(spec))
    }
}

/// Result of replaying one trace.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// End-to-end latency per request (submit -> response), ms, in
    /// completion order.
    pub latencies_ms: Vec<f64>,
    /// Wall time of the whole replay, seconds.
    pub wall_s: f64,
    /// Achieved throughput, images/s.
    pub throughput: f64,
    /// Requests that failed.
    pub failures: usize,
}

impl ReplayReport {
    pub fn latency_stats(&self) -> SampleStats {
        SampleStats::from(&self.latencies_ms)
    }

    /// Fraction of requests meeting a latency SLO.
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().filter(|&&l| l <= slo_ms).count() as f64
            / self.latencies_ms.len() as f64
    }
}

/// Replay a trace against any [`Submit`] sink — a single coordinator or
/// a [`ReplicaSet`] — honoring arrival times (open-loop). Blocks until
/// every request completes. Thin projection of [`replay_qos`]: the
/// trace's QoS metadata is honored (not dropped), and
/// rejections/expiries fold into the aggregate `failures` count.
pub fn replay<S: Submit>(sink: &S, trace: &[TraceEntry]) -> Result<ReplayReport> {
    let report = replay_qos(sink, trace)?;
    let failures = report.outcomes.len() - report.completed();
    Ok(ReplayReport {
        latencies_ms: report.latencies_ms,
        wall_s: report.wall_s,
        throughput: report.throughput,
        failures,
    })
}

/// How one traced request ended — the per-request QoS record.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RequestOutcome {
    Completed { latency_ms: f64 },
    /// Shed at admission (429/503) — never occupied queue space.
    Rejected,
    /// Expired in the queue past its deadline (504).
    DeadlineMissed,
    /// Engine or coordinator failure.
    Failed,
}

/// Replay result with per-request QoS outcomes, in trace order.
#[derive(Debug, Clone)]
pub struct QosReplayReport {
    pub outcomes: Vec<RequestOutcome>,
    /// Telemetry trace id per entry, aligned with `outcomes`. `None`
    /// when the sink runs without telemetry or the request was shed at
    /// admission (its span, if any, closed before a ticket existed).
    pub trace_ids: Vec<Option<u64>>,
    /// Latencies of completed requests only, ms.
    pub latencies_ms: Vec<f64>,
    /// Wall time of the whole replay, seconds.
    pub wall_s: f64,
    /// Completed images/s.
    pub throughput: f64,
}

impl QosReplayReport {
    pub fn completed(&self) -> usize {
        self.latencies_ms.len()
    }

    pub fn rejected(&self) -> usize {
        self.outcomes.iter().filter(|o| matches!(o, RequestOutcome::Rejected)).count()
    }

    pub fn deadline_missed(&self) -> usize {
        self.outcomes
            .iter()
            .filter(|o| matches!(o, RequestOutcome::DeadlineMissed))
            .count()
    }

    pub fn failures(&self) -> usize {
        self.outcomes.iter().filter(|o| matches!(o, RequestOutcome::Failed)).count()
    }

    /// Fraction of *offered* requests completed within `slo_ms` —
    /// rejected, expired and failed requests count against attainment.
    pub fn slo_attainment(&self, slo_ms: f64) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        let met = self
            .outcomes
            .iter()
            .filter(|o| {
                matches!(o, RequestOutcome::Completed { latency_ms } if *latency_ms <= slo_ms)
            })
            .count();
        met as f64 / self.outcomes.len() as f64
    }
}

/// Replay a trace through the QoS submission path of any [`Submit`]
/// sink, recording one [`RequestOutcome`] per entry (open-loop; blocks
/// until every admitted request resolves). Unlike [`replay`],
/// synchronous admission rejections are recorded instead of treated as
/// failures.
pub fn replay_qos<S: Submit>(sink: &S, trace: &[TraceEntry]) -> Result<QosReplayReport> {
    replay_driver(
        trace,
        &[],
        |entry| sink.submit_qos(entry.request.clone(), entry.meta),
        |_| Ok(()),
    )
}

/// Replay a trace against a [`ReplicaSet`] with failure injection: each
/// [`KillSpec`] ejects its replica at its offset, mid-replay. In-flight
/// work on the killed replica requeues onto survivors (the cluster's
/// relay layer), so the report shows where requests actually ended up —
/// the `/stats` ejection/requeue counters carry the injection's audit
/// trail.
pub fn replay_qos_cluster(
    set: &Arc<ReplicaSet>,
    trace: &[TraceEntry],
    kills: &[KillSpec],
) -> Result<QosReplayReport> {
    // validate up front: kills fire on detached threads mid-replay, so a
    // bad replica index must fail loudly here, not be swallowed there
    for k in kills {
        if k.replica >= set.replicas() {
            return Err(Error::Config(format!(
                "kill at {} ms addresses replica {} but the cluster has {}",
                k.at_ms,
                k.replica,
                set.replicas()
            )));
        }
    }
    replay_driver(
        trace,
        kills,
        |entry| set.submit_qos(entry.request.clone(), entry.meta),
        |kill| set.kill(kill.replica),
    )
}

/// Sleep (if needed) until `at_ms` past `start` — open-loop pacing.
fn sleep_until(start: Instant, at_ms: f64) {
    let target = Duration::from_secs_f64(at_ms.max(0.0) / 1e3);
    let now = start.elapsed();
    if target > now {
        std::thread::sleep(target - now);
    }
}

/// Shared open-loop replay engine: merges the arrival stream with the
/// (sorted-by-offset) kill events, fires both at their offsets, then
/// collects one outcome per trace entry. Kill events fire on their own
/// (scope-joined) threads: ejecting a replica blocks until its cohort
/// drains, which must not stall the arrival schedule.
fn replay_driver(
    trace: &[TraceEntry],
    kills: &[KillSpec],
    mut submit: impl FnMut(&TraceEntry) -> Result<crate::coordinator::Ticket>,
    kill: impl Fn(&KillSpec) -> Result<()> + Sync,
) -> Result<QosReplayReport> {
    let mut kills: Vec<KillSpec> = kills.to_vec();
    kills.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
    let start = Instant::now();
    std::thread::scope(|scope| {
        let kill = &kill;
        let mut fire = |spec: KillSpec| {
            sleep_until(start, spec.at_ms);
            scope.spawn(move || {
                // indices are pre-validated by the callers, so the only
                // error here is an already-dead replica: a no-op
                let _ = kill(&spec);
            });
        };
        let mut next_kill = 0usize;
        let mut pending = Vec::with_capacity(trace.len());
        for entry in trace {
            // fire kill events due before this arrival, each at its own
            // offset so a kill between two arrivals lands on time
            while next_kill < kills.len() && kills[next_kill].at_ms <= entry.at_ms {
                fire(kills[next_kill]);
                next_kill += 1;
            }
            sleep_until(start, entry.at_ms);
            match submit(entry) {
                Ok(ticket) => pending.push(Some(ticket)),
                Err(Error::Rejected { .. }) => pending.push(None),
                Err(e) => return Err(e), // setup errors (validation, drain) abort
            }
        }
        // kill events scheduled past the last arrival still fire
        while next_kill < kills.len() {
            fire(kills[next_kill]);
            next_kill += 1;
        }
        let mut outcomes = Vec::with_capacity(trace.len());
        let mut trace_ids = Vec::with_capacity(trace.len());
        let mut latencies = Vec::new();
        for slot in pending {
            match slot {
                None => {
                    outcomes.push(RequestOutcome::Rejected);
                    trace_ids.push(None);
                }
                Some(ticket) => {
                    trace_ids.push(ticket.trace());
                    match ticket.wait_timed() {
                        Ok((_, latency)) => {
                            let ms = latency.as_secs_f64() * 1e3;
                            latencies.push(ms);
                            outcomes.push(RequestOutcome::Completed { latency_ms: ms });
                        }
                        Err(Error::DeadlineExceeded(_)) => {
                            outcomes.push(RequestOutcome::DeadlineMissed)
                        }
                        Err(_) => outcomes.push(RequestOutcome::Failed),
                    }
                }
            }
        }
        let wall_s = start.elapsed().as_secs_f64();
        let throughput = latencies.len() as f64 / wall_s;
        Ok(QosReplayReport { outcomes, trace_ids, latencies_ms: latencies, wall_s, throughput })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::forall;

    #[test]
    fn poisson_arrivals_sorted_and_rate_correct() {
        let ap = ArrivalProcess::Poisson { rate_per_s: 100.0 };
        let arr = ap.arrivals(2000, 1);
        assert_eq!(arr.len(), 2000);
        assert!(arr.windows(2).all(|w| w[1] >= w[0]));
        // mean gap ~ 10ms within 10%
        let mean_gap = arr.last().unwrap() / 2000.0;
        assert!((mean_gap - 10.0).abs() < 1.0, "mean gap {mean_gap}ms");
    }

    #[test]
    fn uniform_arrivals_exact() {
        let ap = ArrivalProcess::Uniform { rate_per_s: 10.0 };
        let arr = ap.arrivals(5, 0);
        assert_eq!(arr, vec![0.0, 100.0, 200.0, 300.0, 400.0]);
    }

    #[test]
    fn arrivals_deterministic_by_seed() {
        let ap = ArrivalProcess::Poisson { rate_per_s: 5.0 };
        assert_eq!(ap.arrivals(50, 7), ap.arrivals(50, 7));
        assert_ne!(ap.arrivals(50, 7), ap.arrivals(50, 8));
    }

    #[test]
    fn bursty_arrivals_monotone() {
        let ap = ArrivalProcess::Bursty { burst_rate_per_s: 50.0, on_ms: 100, off_ms: 400 };
        let arr = ap.arrivals(100, 3);
        assert!(arr.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    fn bursty_arrivals_land_inside_on_windows() {
        // the fold maps the compressed on-only timeline onto wall clock
        // by inserting the off gap each cycle, so every arrival must sit
        // inside an on-window: t mod (on+off) < on
        let (on, off) = (100u64, 400u64);
        let ap = ArrivalProcess::Bursty { burst_rate_per_s: 80.0, on_ms: on, off_ms: off };
        let period = (on + off) as f64;
        for seed in [0u64, 1, 2, 3] {
            let arr = ap.arrivals(300, seed);
            for &t in &arr {
                let phase = t.rem_euclid(period);
                assert!(
                    phase < on as f64 + 1e-9,
                    "seed {seed}: arrival {t} at phase {phase} inside the off window"
                );
            }
        }
    }

    #[test]
    fn bursty_deterministic_and_seed_sensitive() {
        let ap = ArrivalProcess::Bursty { burst_rate_per_s: 30.0, on_ms: 50, off_ms: 150 };
        assert_eq!(ap.arrivals(64, 9), ap.arrivals(64, 9));
        assert_ne!(ap.arrivals(64, 9), ap.arrivals(64, 10));
    }

    #[test]
    fn poisson_mean_gap_tracks_rate_across_seeds() {
        // mean-gap sanity at a second operating point, over several seeds
        let ap = ArrivalProcess::Poisson { rate_per_s: 25.0 };
        for seed in [11u64, 22, 33] {
            let arr = ap.arrivals(1500, seed);
            let mean_gap = arr.last().unwrap() / 1500.0;
            assert!((mean_gap - 40.0).abs() < 6.0, "seed {seed}: mean gap {mean_gap}ms");
        }
    }

    #[test]
    fn trace_synthesis_covers_corpus() {
        use crate::guidance::WindowSpec;
        let spec = WorkloadSpec {
            num_requests: 70,
            schedule: GuidanceSchedule::Window(WindowSpec::last(0.2)),
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 70);
        // prompts cycle through Table 2
        assert_eq!(trace[0].request.prompt, prompts::TABLE2[0]);
        assert_eq!(trace[61].request.prompt, prompts::TABLE2[0]);
        // every request carries the spec's policy and a distinct seed
        assert!(trace
            .iter()
            .all(|t| t.request.schedule == GuidanceSchedule::Window(WindowSpec::last(0.2))));
        let mut seeds: Vec<u64> = trace.iter().map(|t| t.request.seed).collect();
        seeds.dedup();
        assert_eq!(seeds.len(), 70);
    }

    #[test]
    fn trace_carries_strategy() {
        use crate::guidance::ReuseKind;
        let strategy = GuidanceStrategy::Reuse { kind: ReuseKind::Extrapolate, refresh_every: 3 };
        let spec = WorkloadSpec {
            num_requests: 6,
            schedule: GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 },
            strategy,
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert!(trace.iter().all(|t| t.request.strategy == strategy));
        assert!(trace
            .iter()
            .all(|t| t.request.schedule == GuidanceSchedule::Interval { lo: 0.2, hi: 0.8 }));
        // default spec keeps the paper's drop-guidance mode
        let plain = WorkloadSpec { num_requests: 2, ..WorkloadSpec::default() }.synthesize();
        assert!(plain.iter().all(|t| t.request.strategy == GuidanceStrategy::CondOnly));
    }

    #[test]
    fn trace_mixed_step_classes_cycle() {
        let spec = WorkloadSpec {
            num_requests: 7,
            steps_choices: vec![20, 30, 50],
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        let got: Vec<usize> = trace.iter().map(|t| t.request.steps).collect();
        assert_eq!(got, vec![20, 30, 50, 20, 30, 50, 20]);
        // empty choices keep the uniform step count
        let plain = WorkloadSpec { num_requests: 3, ..WorkloadSpec::default() }.synthesize();
        assert!(plain.iter().all(|t| t.request.steps == 50));
    }

    #[test]
    fn trace_carries_qos_meta() {
        let spec = WorkloadSpec {
            num_requests: 5,
            deadline_ms: Some(1500.0),
            priority: Priority::Interactive,
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert!(trace.iter().all(|t| {
            t.meta.priority == Priority::Interactive
                && (t.meta.deadline_ms().unwrap() - 1500.0).abs() < 1e-9
        }));
        // default: best-effort standard
        let plain = WorkloadSpec { num_requests: 2, ..WorkloadSpec::default() }.synthesize();
        assert!(plain.iter().all(|t| t.meta == QosMeta::default()));
    }

    #[test]
    fn planner_opt_out_rides_the_trace() {
        // default: every entry is frontier-eligible
        let plain = WorkloadSpec { num_requests: 3, ..WorkloadSpec::default() }.synthesize();
        assert!(plain.iter().all(|t| !t.meta.planner_opt_out));
        // planner = false marks every entry opted out, composing with
        // the rest of the QoS metadata
        let spec = WorkloadSpec {
            num_requests: 3,
            planner: false,
            deadline_ms: Some(900.0),
            priority: Priority::Interactive,
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert!(trace.iter().all(|t| {
            t.meta.planner_opt_out
                && t.meta.priority == Priority::Interactive
                && (t.meta.deadline_ms().unwrap() - 900.0).abs() < 1e-9
        }));
    }

    #[test]
    fn kill_spec_rides_the_workload_spec() {
        let spec = WorkloadSpec {
            num_requests: 4,
            kills: vec![KillSpec { at_ms: 50.0, replica: 1 }],
            ..WorkloadSpec::default()
        };
        assert_eq!(spec.kills, vec![KillSpec { at_ms: 50.0, replica: 1 }]);
        // kill events are cluster events, not requests: the trace stays
        // one entry per request
        assert_eq!(spec.synthesize().len(), 4);
        // default: no injection
        assert!(WorkloadSpec::default().kills.is_empty());
    }

    #[test]
    fn zipf_ranks_deterministic_and_skew_concentrates() {
        let z = ZipfPrompts { skew: 1.1, catalog: 50 };
        z.validate().unwrap();
        let a = z.ranks(500, 7);
        assert_eq!(a, z.ranks(500, 7));
        assert_ne!(a, z.ranks(500, 8));
        assert!(a.iter().all(|&r| r < 50));
        // higher skew puts more mass on the head of the catalog
        let head = |skew: f64| {
            ZipfPrompts { skew, catalog: 50 }
                .ranks(2000, 7)
                .iter()
                .filter(|&&r| r < 5)
                .count()
        };
        assert!(head(1.5) > head(0.4), "skew 1.5 head {} <= skew 0.4 head {}", head(1.5), head(0.4));
        // invalid shapes are config errors
        assert!(ZipfPrompts { skew: -0.1, catalog: 50 }.validate().is_err());
        assert!(ZipfPrompts { skew: f64::NAN, catalog: 50 }.validate().is_err());
        assert!(ZipfPrompts { skew: 1.0, catalog: 0 }.validate().is_err());
    }

    #[test]
    fn zipf_repeats_are_exact_duplicates() {
        let spec = WorkloadSpec {
            num_requests: 200,
            steps: 8,
            steps_choices: vec![8, 12],
            zipf: Some(ZipfPrompts { skew: 1.2, catalog: 10 }),
            ..WorkloadSpec::default()
        };
        let ranks = spec.zipf.unwrap().ranks(spec.num_requests, spec.seed);
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 200);
        // same rank -> identical request identity (prompt, seed, steps);
        // distinct ranks -> distinct seeds even when prompts alias
        for (i, e) in trace.iter().enumerate() {
            for (j, f) in trace.iter().enumerate().skip(i + 1) {
                if ranks[i] == ranks[j] {
                    assert_eq!(e.request.prompt, f.request.prompt);
                    assert_eq!(e.request.seed, f.request.seed);
                    assert_eq!(e.request.steps, f.request.steps);
                } else {
                    assert_ne!(e.request.seed, f.request.seed);
                }
            }
        }
        // at skew 1.2 over a 10-prompt catalog, duplicates dominate
        let mut distinct = ranks.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() < 20);
        // no zipf: the classic walk keeps one distinct seed per entry
        let plain = WorkloadSpec { num_requests: 5, ..WorkloadSpec::default() }.synthesize();
        let mut seeds: Vec<u64> = plain.iter().map(|t| t.request.seed).collect();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
    }

    #[test]
    fn strength_makes_every_entry_img2img() {
        let spec = WorkloadSpec {
            num_requests: 6,
            steps: 40,
            strength: Some(0.3),
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 6);
        for e in &trace {
            let init = e.request.init.as_ref().expect("img2img init");
            assert!((init.strength - 0.3).abs() < 1e-12);
            assert!(init.latent.is_none(), "workload img2img is synthetic");
            // the truncation the plan is priced over: round(40 * 0.3)
            assert_eq!(e.request.executed_steps(), 12);
        }
        // default stays pure text2img
        let plain = WorkloadSpec { num_requests: 2, ..WorkloadSpec::default() }.synthesize();
        assert!(plain.iter().all(|t| t.request.init.is_none()));
    }

    #[test]
    fn variations_fan_out_shares_one_plan() {
        let spec = WorkloadSpec {
            num_requests: 3,
            variations: 4,
            ..WorkloadSpec::default()
        };
        let trace = spec.synthesize();
        assert_eq!(trace.len(), 12);
        for group in trace.chunks(4) {
            // one arrival: same offset, same prompt, one shared plan
            assert!(group.iter().all(|e| e.at_ms == group[0].at_ms));
            assert!(group.iter().all(|e| e.request.prompt == group[0].request.prompt));
            let plan = group[0].request.shared_plan.as_ref().expect("shared plan");
            for e in &group[1..] {
                assert!(Arc::ptr_eq(plan, e.request.shared_plan.as_ref().unwrap()));
            }
            // seeds walk base..base+4 within the group
            let seeds: Vec<u64> = group.iter().map(|e| e.request.seed).collect();
            assert_eq!(seeds, (seeds[0]..seeds[0] + 4).collect::<Vec<_>>());
        }
        // rank spacing keeps seeds globally distinct across arrivals
        let mut all: Vec<u64> = trace.iter().map(|e| e.request.seed).collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 12);
        // plans are NOT shared across arrivals (each group compiles once)
        assert!(!Arc::ptr_eq(
            trace[0].request.shared_plan.as_ref().unwrap(),
            trace[4].request.shared_plan.as_ref().unwrap()
        ));
    }

    #[test]
    fn workload_toml_section() {
        use crate::config::{EngineConfig, TomlDoc};
        let engine = EngineConfig { steps: 30, ..EngineConfig::default() };
        // absent section -> no spec
        let doc = TomlDoc::parse("[server]\nmax_batch = 2\n").unwrap();
        assert!(WorkloadSpec::from_toml(&doc, &engine).unwrap().is_none());
        // present section inherits the engine policy, overrides traffic
        let doc = TomlDoc::parse(
            "[workload]\narrival = \"uniform\"\nrate_per_s = 20.0\nrequests = 12\n\
             strength = 0.4\nvariations = 3\ndeadline_ms = 800.0\npriority = \"interactive\"\n",
        )
        .unwrap();
        let spec = WorkloadSpec::from_toml(&doc, &engine).unwrap().unwrap();
        assert_eq!(spec.arrivals, ArrivalProcess::Uniform { rate_per_s: 20.0 });
        assert_eq!(spec.num_requests, 12);
        assert_eq!(spec.steps, 30, "inherits [engine] steps");
        assert_eq!(spec.strength, Some(0.4));
        assert_eq!(spec.variations, 3);
        assert_eq!(spec.deadline_ms, Some(800.0));
        assert_eq!(spec.priority, Priority::Interactive);
        // bursty + zipf forms
        let doc = TomlDoc::parse(
            "[workload]\narrival = \"bursty\"\nrate_per_s = 50.0\non_ms = 80\noff_ms = 320\n\
             zipf_skew = 1.1\nzipf_catalog = 16\n",
        )
        .unwrap();
        let spec = WorkloadSpec::from_toml(&doc, &engine).unwrap().unwrap();
        assert_eq!(
            spec.arrivals,
            ArrivalProcess::Bursty { burst_rate_per_s: 50.0, on_ms: 80, off_ms: 320 }
        );
        assert_eq!(spec.zipf, Some(ZipfPrompts { skew: 1.1, catalog: 16 }));
        // empty section = all defaults, engine-seeded
        let doc = TomlDoc::parse("[workload]\n").unwrap();
        let spec = WorkloadSpec::from_toml(&doc, &engine).unwrap().unwrap();
        assert_eq!(spec.steps, 30);
        assert_eq!(spec.variations, 1);
        assert_eq!(spec.strength, None);
        assert!(spec.planner, "frontier-eligible by default");
        // planner = false opts the whole trace out of frontier search
        let doc = TomlDoc::parse("[workload]\nplanner = false\n").unwrap();
        let spec = WorkloadSpec::from_toml(&doc, &engine).unwrap().unwrap();
        assert!(!spec.planner);
        assert!(spec.synthesize().iter().all(|t| t.meta.planner_opt_out));
    }

    #[test]
    fn workload_toml_rejects_bad_shapes() {
        use crate::config::{EngineConfig, TomlDoc};
        let engine = EngineConfig::default();
        let parse = |s: &str| {
            WorkloadSpec::from_toml(&TomlDoc::parse(s).unwrap(), &engine).map(|_| ())
        };
        assert!(parse("[workload]\narrival = \"bogus\"\n").is_err());
        assert!(parse("[workload]\nrate_per_s = 0.0\n").is_err());
        assert!(parse("[workload]\nrate_per_s = -2.0\n").is_err());
        assert!(parse("[workload]\nrequests = 0\n").is_err());
        // burst knobs require the bursty process (orphan-knob rule)
        assert!(parse("[workload]\non_ms = 50\n").is_err());
        assert!(parse("[workload]\narrival = \"uniform\"\noff_ms = 50\n").is_err());
        assert!(parse("[workload]\narrival = \"bursty\"\non_ms = 0\n").is_err());
        // streaming-plane knobs validate at parse, not at submit
        assert!(parse("[workload]\nstrength = 0.0\n").is_err());
        assert!(parse("[workload]\nstrength = 1.5\n").is_err());
        assert!(parse("[workload]\nvariations = 0\n").is_err());
        assert!(parse("[workload]\nvariations = \"many\"\n").is_err());
        assert!(parse("[workload]\nplanner = \"off\"\n").is_err());
        // zipf knobs come as a pair
        assert!(parse("[workload]\nzipf_skew = 1.0\n").is_err());
        assert!(parse("[workload]\nzipf_catalog = 8\n").is_err());
        assert!(parse("[workload]\nzipf_skew = -1.0\nzipf_catalog = 8\n").is_err());
        // shared validations
        assert!(parse("[workload]\nseed = -4\n").is_err());
        assert!(parse("[workload]\npriority = \"vip\"\n").is_err());
        assert!(parse("[workload]\ndeadline_ms = -5.0\n").is_err());
        assert!(parse("[workload]\nscheduler = \"bogus\"\n").is_err());
    }

    #[test]
    fn seed_setter_shares_validation() {
        let spec = WorkloadSpec::default().with_seed_i64(42).unwrap();
        assert_eq!(spec.seed, 42);
        let err = WorkloadSpec::default().with_seed_i64(-3).unwrap_err();
        assert!(err.to_string().contains("seed must be >= 0"));
    }

    #[test]
    fn replay_report_slo_math() {
        let report = ReplayReport {
            latencies_ms: vec![10.0, 20.0, 30.0, 40.0],
            wall_s: 1.0,
            throughput: 4.0,
            failures: 0,
        };
        assert_eq!(report.slo_attainment(25.0), 0.5);
        assert_eq!(report.slo_attainment(100.0), 1.0);
        assert_eq!(report.slo_attainment(5.0), 0.0);
    }

    #[test]
    fn qos_replay_report_math() {
        let report = QosReplayReport {
            outcomes: vec![
                RequestOutcome::Completed { latency_ms: 10.0 },
                RequestOutcome::Completed { latency_ms: 40.0 },
                RequestOutcome::Rejected,
                RequestOutcome::DeadlineMissed,
                RequestOutcome::Failed,
            ],
            trace_ids: vec![Some(0), Some(1), None, Some(3), Some(4)],
            latencies_ms: vec![10.0, 40.0],
            wall_s: 1.0,
            throughput: 2.0,
        };
        assert_eq!(report.completed(), 2);
        assert_eq!(report.rejected(), 1);
        assert_eq!(report.deadline_missed(), 1);
        assert_eq!(report.failures(), 1);
        // only completions inside the SLO count; shed/expired/failed
        // requests count against attainment
        assert!((report.slo_attainment(25.0) - 0.2).abs() < 1e-12);
        assert!((report.slo_attainment(100.0) - 0.4).abs() < 1e-12);
        assert_eq!(report.slo_attainment(1.0), 0.0);
    }

    #[test]
    fn arrival_rates_scale_property() {
        forall("arrival rate scaling", 20, |g| {
            let rate = g.f64_in(1.0, 200.0);
            let ap = ArrivalProcess::Poisson { rate_per_s: rate };
            let n = 500;
            let arr = ap.arrivals(n, g.u64());
            let measured_rate = n as f64 / (arr.last().unwrap() / 1e3);
            assert!(
                (measured_rate - rate).abs() / rate < 0.25,
                "target {rate}/s, measured {measured_rate}/s"
            );
        });
    }
}
