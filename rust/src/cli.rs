//! Hand-rolled CLI argument parsing (no `clap` in the offline snapshot).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand, `--key value` options and flags.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an argument iterator (excluding `argv[0]`).
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Result<Cli> {
        let mut cli = Cli::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    cli.options.insert(name.to_string(), v);
                } else {
                    cli.flags.push(name.to_string());
                }
            } else if cli.command.is_none() {
                cli.command = Some(arg);
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn parse() -> Result<Cli> {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.opt(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| Error::Config(format!("--{name}: cannot parse {s:?}"))),
        }
    }

    pub fn opt_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        Ok(self.opt_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Cli {
        Cli::parse_from(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let c = parse(&["generate", "--prompt", "a cat", "--steps", "25", "--fast"]);
        assert_eq!(c.command.as_deref(), Some("generate"));
        assert_eq!(c.opt("prompt"), Some("a cat"));
        assert_eq!(c.opt_or::<usize>("steps", 50).unwrap(), 25);
        assert!(c.flag("fast"));
        assert!(!c.flag("slow"));
    }

    #[test]
    fn equals_syntax() {
        let c = parse(&["serve", "--bind=0.0.0.0:9000"]);
        assert_eq!(c.opt("bind"), Some("0.0.0.0:9000"));
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let c = parse(&["x", "--a", "--b", "v"]);
        assert!(c.flag("a"));
        assert_eq!(c.opt("b"), Some("v"));
    }

    #[test]
    fn positional_args() {
        let c = parse(&["bench", "t1", "t2"]);
        assert_eq!(c.positional, vec!["t1", "t2"]);
    }

    #[test]
    fn parse_errors() {
        let c = parse(&["x", "--steps", "abc"]);
        assert!(c.opt_parse::<usize>("steps").is_err());
        assert!(Cli::parse_from(vec!["--".to_string()]).is_err());
    }

    #[test]
    fn default_when_missing() {
        let c = parse(&["x"]);
        assert_eq!(c.opt_or::<u64>("seed", 42).unwrap(), 42);
        assert_eq!(c.opt_parse::<u64>("seed").unwrap(), None);
    }
}
