//! Metrics registry + Prometheus text exposition (format 0.0.4).
//!
//! Counters, gauges and histograms with labels, rendered as valid
//! Prometheus text: one `# HELP` / `# TYPE` pair per family, label
//! values escaped (`\\`, `\"`, `\n`), histogram buckets cumulative and
//! terminated by `le="+Inf"`. Histograms reuse the log-bucketed
//! [`LatencyHistogram`] and project it onto a fixed millisecond `le`
//! ladder at render time, so recording stays O(1) and the exposition is
//! still cumulative-monotone.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are pre-resolved
//! `Arc`s: the registry mutex is taken only at registration and render
//! time, never on the hot update path.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::LatencyHistogram;

/// The `le` ladder (milliseconds) histogram families are projected onto
/// at exposition time. Spans four orders of magnitude around typical
/// request latencies; `+Inf` is always appended.
pub const LE_BOUNDS_MS: [f64; 14] = [
    0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
];

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MetricKind {
    Counter,
    Gauge,
    Histogram,
}

impl MetricKind {
    fn type_name(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Histogram => "histogram",
        }
    }
}

/// Monotone counter handle (u64).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Gauge handle (f64 stored as bits; last-write-wins).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge(Arc::new(AtomicU64::new(0f64.to_bits())))
    }
}

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn set_usize(&self, v: usize) {
        self.set(v as f64);
    }

    pub fn value(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Histogram handle: a shared log-bucketed [`LatencyHistogram`].
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<Mutex<LatencyHistogram>>);

impl Histogram {
    pub fn observe_ms(&self, ms: f64) {
        self.0.lock().expect("histogram lock").record_ms(ms);
    }

    pub fn snapshot(&self) -> LatencyHistogram {
        self.0.lock().expect("histogram lock").clone()
    }
}

enum Series {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

struct Family {
    help: String,
    kind: MetricKind,
    /// Label-set → series, keyed by the sorted label pairs so the
    /// exposition order is deterministic.
    series: BTreeMap<Vec<(String, String)>, Series>,
}

/// The process-wide metric registry every layer reports into.
#[derive(Default)]
pub struct Registry {
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create a counter series. Registering an existing name with
    /// a different kind is a programming error and panics loudly.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.series(name, help, MetricKind::Counter, labels) {
            Series::Counter(c) => c,
            _ => unreachable!(),
        }
    }

    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.series(name, help, MetricKind::Gauge, labels) {
            Series::Gauge(g) => g,
            _ => unreachable!(),
        }
    }

    pub fn histogram(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Histogram {
        match self.series(name, help, MetricKind::Histogram, labels) {
            Series::Histogram(h) => h,
            _ => unreachable!(),
        }
    }

    fn series(&self, name: &str, help: &str, kind: MetricKind, labels: &[(&str, &str)]) -> Series {
        assert!(valid_name(name), "invalid metric name {name:?}");
        for (k, _) in labels {
            assert!(valid_name(k), "invalid label name {k:?}");
        }
        let mut key: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        key.sort();
        let mut families = self.families.lock().expect("registry lock");
        let fam = families.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: BTreeMap::new(),
        });
        assert_eq!(
            fam.kind, kind,
            "metric {name:?} registered as {} and {}",
            fam.kind.type_name(),
            kind.type_name()
        );
        let entry = fam.series.entry(key).or_insert_with(|| match kind {
            MetricKind::Counter => Series::Counter(Counter::default()),
            MetricKind::Gauge => Series::Gauge(Gauge::default()),
            MetricKind::Histogram => Series::Histogram(Histogram::default()),
        });
        match entry {
            Series::Counter(c) => Series::Counter(c.clone()),
            Series::Gauge(g) => Series::Gauge(g.clone()),
            Series::Histogram(h) => Series::Histogram(h.clone()),
        }
    }

    /// Render the whole registry in Prometheus text exposition format.
    pub fn render(&self) -> String {
        let families = self.families.lock().expect("registry lock");
        let mut out = String::new();
        for (name, fam) in families.iter() {
            out.push_str(&format!("# HELP {name} {}\n", escape_help(&fam.help)));
            out.push_str(&format!("# TYPE {name} {}\n", fam.kind.type_name()));
            for (labels, series) in fam.series.iter() {
                match series {
                    Series::Counter(c) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            label_block(labels, None),
                            c.value()
                        ));
                    }
                    Series::Gauge(g) => {
                        out.push_str(&format!(
                            "{name}{} {}\n",
                            label_block(labels, None),
                            fmt_f64(g.value())
                        ));
                    }
                    Series::Histogram(h) => {
                        let snap = h.snapshot();
                        let cum = snap.cumulative_le(&LE_BOUNDS_MS);
                        for (bound, count) in LE_BOUNDS_MS.iter().zip(cum.iter()) {
                            out.push_str(&format!(
                                "{name}_bucket{} {count}\n",
                                label_block(labels, Some(&fmt_f64(*bound))),
                            ));
                        }
                        out.push_str(&format!(
                            "{name}_bucket{} {}\n",
                            label_block(labels, Some("+Inf")),
                            snap.count()
                        ));
                        out.push_str(&format!(
                            "{name}_sum{} {}\n",
                            label_block(labels, None),
                            fmt_f64(snap.sum_ms())
                        ));
                        out.push_str(&format!(
                            "{name}_count{} {}\n",
                            label_block(labels, None),
                            snap.count()
                        ));
                    }
                }
            }
        }
        out
    }
}

/// `{k="v",...}` with escaped values; `le` (when given) is appended
/// last, matching Prometheus convention. Empty → empty string.
fn label_block(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .enumerate()
            .all(|(i, c)| c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit()))
}

/// Float formatting for exposition: integral values render without a
/// trailing `.0` (Prometheus parsers accept both; this keeps diffs and
/// tests stable).
fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_render() {
        let r = Registry::new();
        let c = r.counter("sg_test_total", "a counter", &[("scope", "single")]);
        c.inc();
        c.add(2);
        let g = r.gauge("sg_test_depth", "a gauge", &[]);
        g.set(3.5);
        let text = r.render();
        assert!(text.contains("# HELP sg_test_total a counter\n"));
        assert!(text.contains("# TYPE sg_test_total counter\n"));
        assert!(text.contains("sg_test_total{scope=\"single\"} 3\n"));
        assert!(text.contains("# TYPE sg_test_depth gauge\n"));
        assert!(text.contains("sg_test_depth 3.5\n"));
    }

    #[test]
    fn same_series_shares_a_handle() {
        let r = Registry::new();
        let a = r.counter("sg_x_total", "x", &[("k", "v")]);
        // label order must not matter for identity
        let b = r.counter("sg_x_total", "x", &[("k", "v")]);
        a.inc();
        assert_eq!(b.value(), 1);
    }

    #[test]
    #[should_panic(expected = "registered as")]
    fn kind_mismatch_panics() {
        let r = Registry::new();
        r.counter("sg_y", "y", &[]);
        r.gauge("sg_y", "y", &[]);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_in_inf() {
        let r = Registry::new();
        let h = r.histogram("sg_lat_ms", "latency", &[]);
        for ms in [0.3, 0.7, 3.0, 40.0, 40.0, 20_000.0] {
            h.observe_ms(ms);
        }
        let text = r.render();
        let counts: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("sg_lat_ms_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
            .collect();
        assert_eq!(counts.len(), LE_BOUNDS_MS.len() + 1);
        assert!(counts.windows(2).all(|w| w[0] <= w[1]), "{counts:?}");
        assert_eq!(*counts.last().unwrap(), 6, "+Inf bucket must equal count");
        // the 20s sample only lands in +Inf
        assert!(counts[LE_BOUNDS_MS.len() - 1] < 6);
        assert!(text.contains("sg_lat_ms_bucket{le=\"+Inf\"} 6\n"));
        assert!(text.contains("sg_lat_ms_count 6\n"));
    }

    #[test]
    fn label_escaping() {
        let r = Registry::new();
        r.counter("sg_esc_total", "escapes", &[("path", "a\\b\"c\nd")]).inc();
        let text = r.render();
        assert!(text.contains("path=\"a\\\\b\\\"c\\nd\""), "{text}");
    }
}
