//! Clock abstraction behind every telemetry timestamp.
//!
//! Telemetry must be *clock-abstracted* (DESIGN.md §12): the virtual-time
//! benches and the QoS simulator need deterministic timestamps, while the
//! serving path wants plain wall time. A [`Clock`] is either:
//!
//! * **wall** — monotonic time since the clock was created
//!   ([`std::time::Instant`] under the hood), or
//! * **manual** — a shared atomic nanosecond counter advanced explicitly
//!   by the driver (one cohort iteration == one tick in the benches).
//!
//! Clones share the same time source, so a manual clock handed to the
//! telemetry layer and to the test driver stays in lock-step.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotonic nanosecond clock: wall time or a manually advanced
/// virtual counter. Cheap to clone; clones share the time source.
#[derive(Clone, Debug)]
pub struct Clock {
    inner: Inner,
}

#[derive(Clone, Debug)]
enum Inner {
    Wall(Instant),
    Manual(Arc<AtomicU64>),
}

impl Clock {
    /// Wall time, anchored at creation (reads are monotonic deltas).
    pub fn wall() -> Clock {
        Clock { inner: Inner::Wall(Instant::now()) }
    }

    /// A virtual clock starting at 0 ns, advanced only by
    /// [`Clock::advance_ns`] — the deterministic benches' time source.
    pub fn manual() -> Clock {
        Clock { inner: Inner::Manual(Arc::new(AtomicU64::new(0))) }
    }

    pub fn is_manual(&self) -> bool {
        matches!(self.inner, Inner::Manual(_))
    }

    /// Nanoseconds since the clock's origin.
    pub fn now_ns(&self) -> u64 {
        match &self.inner {
            Inner::Wall(t0) => t0.elapsed().as_nanos() as u64,
            Inner::Manual(ns) => ns.load(Ordering::Relaxed),
        }
    }

    pub fn now_ms(&self) -> f64 {
        self.now_ns() as f64 / 1e6
    }

    /// Elapsed nanoseconds since an earlier [`Clock::now_ns`] reading.
    pub fn since_ns(&self, start_ns: u64) -> u64 {
        self.now_ns().saturating_sub(start_ns)
    }

    /// Advance a manual clock; a no-op on a wall clock (wall time cannot
    /// be steered, and benches that accidentally mix the two should not
    /// crash the serving path).
    pub fn advance_ns(&self, ns: u64) {
        if let Inner::Manual(t) = &self.inner {
            t.fetch_add(ns, Ordering::Relaxed);
        }
    }

    pub fn advance_ms(&self, ms: f64) {
        self.advance_ns((ms * 1e6) as u64);
    }

    /// Elapsed time since `start_ns` as a [`Duration`].
    pub fn since(&self, start_ns: u64) -> Duration {
        Duration::from_nanos(self.since_ns(start_ns))
    }
}

impl Default for Clock {
    fn default() -> Clock {
        Clock::wall()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_is_deterministic() {
        let c = Clock::manual();
        assert!(c.is_manual());
        assert_eq!(c.now_ns(), 0);
        c.advance_ns(1_500);
        assert_eq!(c.now_ns(), 1_500);
        c.advance_ms(2.0);
        assert_eq!(c.now_ns(), 2_001_500);
        assert!((c.now_ms() - 2.0015).abs() < 1e-12);
    }

    #[test]
    fn clones_share_the_time_source() {
        let a = Clock::manual();
        let b = a.clone();
        a.advance_ns(42);
        assert_eq!(b.now_ns(), 42);
        assert_eq!(b.since_ns(40), 2);
    }

    #[test]
    fn wall_clock_marches_forward() {
        let c = Clock::wall();
        assert!(!c.is_manual());
        let t0 = c.now_ns();
        std::thread::sleep(Duration::from_millis(2));
        assert!(c.now_ns() > t0);
        // advancing a wall clock is an explicit no-op
        c.advance_ns(u64::MAX / 2);
        assert!(c.now_ms() < 60_000.0);
    }
}
