//! Structured per-request trace spans.
//!
//! Every request gets a trace ID at admission; each layer appends
//! timestamped [`TraceEvent`]s as the request moves through admission,
//! routing, cohort execution and retirement. Spans live in a bounded
//! ring buffer (oldest evicted first) and are queryable over the wire
//! (`{"op":"trace","trace":N}`) or exportable as JSONL.
//!
//! Terminal events — `rejected`, `retired`, `shed`, `expired`,
//! `cancelled` — close a span. The conservation invariant (enforced by
//! `tests/trace_conservation.rs`): every *admitted* span ends in exactly
//! one terminal event, including requeued failover legs.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Value;

pub type TraceId = u64;

/// One structured event on a request's trace span.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Request passed admission (QoS or open-door).
    Admitted { class: &'static str },
    /// Request refused at admission (terminal).
    Rejected { code: u16, reason: String },
    /// Request entered a queue at the given depth.
    Queued { depth: usize },
    /// Cluster router placed the request on a replica.
    Routed { replica: usize },
    /// Sample joined a continuous cohort of the given size.
    CohortJoin { cohort: usize },
    /// One executed segment of the guidance plan: `mode` is the plan
    /// run-length code (`D` dual, `C` cond-only, `R` reuse, `U`
    /// unguided), `evals` the UNet executions the segment cost.
    PlanExec { mode: char, steps: usize, evals: usize },
    /// QoS actuator rewrote the request's shed fraction at admission.
    ActuatorRewrite { from: f64, to: f64 },
    /// Frontier plan search answered this admission: the selected
    /// Pareto point's predicted quality and priced cost (DESIGN.md §16).
    PlanSearched { ssim: f64, cost_ms: f64 },
    /// Failover: the request left replica `from` and was re-dispatched
    /// onto replica `to`.
    Requeued { from: usize, to: usize },
    /// Served bit-exactly from the exact-match request cache
    /// (non-terminal — the span still closes with `Retired`).
    CacheHit,
    /// Coalesced onto an identical in-flight generation (non-terminal —
    /// the span stays open until the fan-out delivers, then closes with
    /// its own terminal event).
    DedupJoin,
    /// Completed successfully (terminal).
    Retired,
    /// Dropped by load shedding or failure (terminal).
    Shed { reason: String },
    /// Deadline exceeded (terminal).
    Expired,
    /// Client-initiated mid-flight cancel (terminal) — the sample was
    /// aborted without `finish()` and its slots returned to headroom.
    Cancelled,
}

impl TraceEvent {
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::Rejected { .. } => "rejected",
            TraceEvent::Queued { .. } => "queued",
            TraceEvent::Routed { .. } => "routed",
            TraceEvent::CohortJoin { .. } => "cohort_join",
            TraceEvent::PlanExec { .. } => "plan_exec",
            TraceEvent::ActuatorRewrite { .. } => "actuator_rewrite",
            TraceEvent::PlanSearched { .. } => "plan_searched",
            TraceEvent::Requeued { .. } => "requeued",
            TraceEvent::CacheHit => "cache_hit",
            TraceEvent::DedupJoin => "dedup_join",
            TraceEvent::Retired => "retired",
            TraceEvent::Shed { .. } => "shed",
            TraceEvent::Expired => "expired",
            TraceEvent::Cancelled => "cancelled",
        }
    }

    /// Terminal events close the span: exactly one per admitted request.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            TraceEvent::Rejected { .. }
                | TraceEvent::Retired
                | TraceEvent::Shed { .. }
                | TraceEvent::Expired
                | TraceEvent::Cancelled
        )
    }

    fn fields(&self, v: Value) -> Value {
        match self {
            TraceEvent::Admitted { class } => v.with("class", *class),
            TraceEvent::Rejected { code, reason } => {
                v.with("code", *code as i64).with("reason", reason.as_str())
            }
            TraceEvent::Queued { depth } => v.with("depth", *depth as i64),
            TraceEvent::Routed { replica } => v.with("replica", *replica as i64),
            TraceEvent::CohortJoin { cohort } => v.with("cohort", *cohort as i64),
            TraceEvent::PlanExec { mode, steps, evals } => v
                .with("mode", mode.to_string())
                .with("steps", *steps as i64)
                .with("evals", *evals as i64),
            TraceEvent::ActuatorRewrite { from, to } => v.with("from", *from).with("to", *to),
            TraceEvent::PlanSearched { ssim, cost_ms } => {
                v.with("ssim", *ssim).with("cost_ms", *cost_ms)
            }
            TraceEvent::Requeued { from, to } => {
                v.with("from", *from as i64).with("to", *to as i64)
            }
            TraceEvent::CacheHit | TraceEvent::DedupJoin => v,
            TraceEvent::Retired
            | TraceEvent::Shed { .. }
            | TraceEvent::Expired
            | TraceEvent::Cancelled => {
                if let TraceEvent::Shed { reason } = self {
                    v.with("reason", reason.as_str())
                } else {
                    v
                }
            }
        }
    }
}

/// A timestamped event (nanoseconds on the telemetry clock).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub at_ns: u64,
    pub event: TraceEvent,
}

/// One request's event history.
#[derive(Clone, Debug)]
pub struct Span {
    pub id: TraceId,
    pub events: Vec<SpanEvent>,
}

impl Span {
    /// Number of terminal events recorded (the conservation invariant
    /// requires exactly 1 on every admitted span).
    pub fn terminal_events(&self) -> usize {
        self.events.iter().filter(|e| e.event.is_terminal()).count()
    }

    pub fn has(&self, name: &str) -> bool {
        self.events.iter().any(|e| e.event.name() == name)
    }

    pub fn to_json(&self) -> Value {
        let events: Vec<Value> = self
            .events
            .iter()
            .map(|e| {
                e.event.fields(
                    Value::obj()
                        .with("t_ms", e.at_ns as f64 / 1e6)
                        .with("event", e.event.name()),
                )
            })
            .collect();
        Value::obj()
            .with("trace_id", self.id as i64)
            .with("terminated", self.terminal_events() > 0)
            .with("events", Value::Arr(events))
    }
}

struct Ring {
    order: VecDeque<TraceId>,
    spans: HashMap<TraceId, Span>,
}

/// Bounded ring buffer of spans; oldest evicted first.
pub struct TraceStore {
    capacity: usize,
    next: AtomicU64,
    evicted: AtomicU64,
    inner: Mutex<Ring>,
}

impl TraceStore {
    pub fn new(capacity: usize) -> TraceStore {
        TraceStore {
            capacity: capacity.max(1),
            next: AtomicU64::new(1),
            evicted: AtomicU64::new(0),
            inner: Mutex::new(Ring { order: VecDeque::new(), spans: HashMap::new() }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Open a new span and return its trace ID (IDs start at 1 and never
    /// repeat). Evicts the oldest span when the ring is full.
    pub fn begin(&self) -> TraceId {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.inner.lock().expect("trace lock");
        if ring.order.len() >= self.capacity {
            if let Some(old) = ring.order.pop_front() {
                ring.spans.remove(&old);
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
        ring.order.push_back(id);
        ring.spans.insert(id, Span { id, events: Vec::new() });
        id
    }

    /// Append an event. Unknown IDs (already evicted) are dropped
    /// silently — the ring is a bounded observability buffer, not an
    /// accounting ledger.
    pub fn record(&self, id: TraceId, at_ns: u64, event: TraceEvent) {
        let mut ring = self.inner.lock().expect("trace lock");
        if let Some(span) = ring.spans.get_mut(&id) {
            span.events.push(SpanEvent { at_ns, event });
        }
    }

    pub fn span(&self, id: TraceId) -> Option<Span> {
        self.inner.lock().expect("trace lock").spans.get(&id).cloned()
    }

    /// The most recent `n` trace IDs, newest last.
    pub fn recent(&self, n: usize) -> Vec<TraceId> {
        let ring = self.inner.lock().expect("trace lock");
        ring.order.iter().rev().take(n).rev().copied().collect()
    }

    /// Snapshot of every live span (ring order, oldest first).
    pub fn spans(&self) -> Vec<Span> {
        let ring = self.inner.lock().expect("trace lock");
        ring.order.iter().filter_map(|id| ring.spans.get(id).cloned()).collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("trace lock").order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans evicted by the ring bound so far.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Export every live span as JSON lines (one span object per line).
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for span in self.spans() {
            out.push_str(&span.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_lifecycle_and_terminal_count() {
        let store = TraceStore::new(8);
        let id = store.begin();
        store.record(id, 10, TraceEvent::Admitted { class: "standard" });
        store.record(id, 20, TraceEvent::Queued { depth: 3 });
        store.record(id, 30, TraceEvent::Retired);
        let span = store.span(id).unwrap();
        assert_eq!(span.events.len(), 3);
        assert_eq!(span.terminal_events(), 1);
        assert!(span.has("queued"));
        let j = span.to_json();
        assert_eq!(j.get("terminated").and_then(Value::as_bool), Some(true));
    }

    #[test]
    fn ring_evicts_oldest() {
        let store = TraceStore::new(2);
        let a = store.begin();
        let b = store.begin();
        let c = store.begin();
        assert_eq!(store.len(), 2);
        assert_eq!(store.evicted(), 1);
        assert!(store.span(a).is_none());
        assert!(store.span(b).is_some());
        assert_eq!(store.recent(10), vec![b, c]);
        // recording onto an evicted span is a silent no-op
        store.record(a, 5, TraceEvent::Retired);
        assert!(store.span(a).is_none());
    }

    #[test]
    fn jsonl_export_is_one_object_per_line() {
        let store = TraceStore::new(4);
        for _ in 0..3 {
            let id = store.begin();
            store.record(id, 1, TraceEvent::Admitted { class: "batch" });
            store.record(id, 2, TraceEvent::Shed { reason: "drain".into() });
        }
        let text = store.export_jsonl();
        assert_eq!(text.lines().count(), 3);
        for line in text.lines() {
            let v = crate::json::from_str(line).unwrap();
            assert!(v.get("trace_id").is_some());
        }
    }

    #[test]
    fn terminal_classification() {
        assert!(TraceEvent::Retired.is_terminal());
        assert!(TraceEvent::Expired.is_terminal());
        assert!(TraceEvent::Cancelled.is_terminal());
        assert_eq!(TraceEvent::Cancelled.name(), "cancelled");
        assert!(TraceEvent::Shed { reason: "x".into() }.is_terminal());
        assert!(TraceEvent::Rejected { code: 429, reason: "q".into() }.is_terminal());
        assert!(!TraceEvent::Admitted { class: "interactive" }.is_terminal());
        assert!(!TraceEvent::Requeued { from: 0, to: 1 }.is_terminal());
        // a frontier search annotates the admission, it never closes it
        assert!(!TraceEvent::PlanSearched { ssim: 0.97, cost_ms: 70.0 }.is_terminal());
        assert_eq!(TraceEvent::PlanSearched { ssim: 0.97, cost_ms: 70.0 }.name(), "plan_searched");
        // cache events never close a span: a hit still retires, a dedup
        // join terminates only at fan-out delivery
        assert!(!TraceEvent::CacheHit.is_terminal());
        assert!(!TraceEvent::DedupJoin.is_terminal());
        assert_eq!(TraceEvent::CacheHit.name(), "cache_hit");
        assert_eq!(TraceEvent::DedupJoin.name(), "dedup_join");
    }
}
