//! Telemetry: Prometheus metrics + per-request trace spans (DESIGN.md §12).
//!
//! One [`Telemetry`] instance per serving process ties together:
//!
//! * a metrics [`Registry`] (counters / gauges / histograms with labels)
//!   rendered in Prometheus text exposition format — served via the
//!   `{"op":"metrics"}` wire operation and the `serve --metrics-addr`
//!   plain-HTTP scrape endpoint;
//! * a bounded [`TraceStore`] of per-request spans — every request gets
//!   a trace ID at admission and accumulates timestamped events
//!   (`admitted`, `routed{replica}`, `cohort_join`, `plan_exec`,
//!   `requeued{from,to}`, terminal `retired`/`shed`/`expired`, …),
//!   queryable via `{"op":"trace","trace":N}` or exported as JSONL;
//! * the [`Clock`] every timestamp comes from — wall time in serving,
//!   manual (virtual) time in the deterministic benches.
//!
//! Layers do not talk to the registry on hot paths: each layer builds a
//! handle bundle once at startup ([`CoordSink`], [`BatcherMetrics`],
//! [`EngineMetrics`], [`QosTelemetry`], [`ClusterMetrics`]) whose
//! methods are a few relaxed atomic ops when enabled and an immediate
//! return when not. A layer without a bundle attached pays nothing —
//! telemetry is strictly opt-in per coordinator/replica-set.
//!
//! **Terminal-event ownership.** In cluster mode a request's span
//! crosses replicas: the replica coordinator that executes a leg must
//! *not* close the span (the cluster relay may requeue the leg onto a
//! survivor after a kill). [`CoordSink`] therefore carries
//! `owns_terminal`: true for a standalone coordinator, false for
//! replica coordinators — there the cluster relay emits the single
//! terminal event. This is what makes the conservation invariant
//! (exactly one terminal event per admitted span) hold under failover.

pub mod clock;
pub mod registry;
pub mod trace;

pub use clock::Clock;
pub use registry::{Counter, Gauge, Histogram, MetricKind, Registry, LE_BOUNDS_MS};
pub use trace::{Span, SpanEvent, TraceEvent, TraceId, TraceStore};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::guidance::{CostTable, PlanSearch, StepMode};
use crate::metrics::StepBreakdown;

/// Default trace ring capacity (spans kept for `{"op":"trace"}`).
pub const DEFAULT_TRACE_CAPACITY: usize = 1024;

/// Content-Type of the Prometheus text exposition format.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// The process-wide telemetry hub: registry + trace store + clock.
pub struct Telemetry {
    enabled: bool,
    clock: Clock,
    registry: Registry,
    traces: TraceStore,
}

impl Telemetry {
    /// Enabled telemetry on the given clock.
    pub fn with_clock(trace_capacity: usize, clock: Clock) -> Arc<Telemetry> {
        Arc::new(Telemetry {
            enabled: true,
            clock,
            registry: Registry::new(),
            traces: TraceStore::new(trace_capacity),
        })
    }

    /// Enabled telemetry, wall clock, default trace capacity.
    pub fn on() -> Arc<Telemetry> {
        Self::with_clock(DEFAULT_TRACE_CAPACITY, Clock::wall())
    }

    /// A disabled instance: every sink built from it is a no-op. (Layers
    /// without any telemetry attached pay even less — nothing at all.)
    pub fn off() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            enabled: false,
            clock: Clock::wall(),
            registry: Registry::new(),
            traces: TraceStore::new(1),
        })
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    pub fn clock(&self) -> &Clock {
        &self.clock
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn traces(&self) -> &TraceStore {
        &self.traces
    }

    /// Open a span (None when disabled).
    pub fn begin_trace(&self) -> Option<TraceId> {
        if !self.enabled {
            return None;
        }
        Some(self.traces.begin())
    }

    /// Append an event to a span, stamped with the telemetry clock.
    pub fn event(&self, trace: Option<TraceId>, ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        if let Some(id) = trace {
            self.traces.record(id, self.clock.now_ns(), ev);
        }
    }

    /// Render every registered metric in Prometheus text format.
    pub fn render_prometheus(&self) -> String {
        self.registry.render()
    }
}

/// Map a rejection code onto a bounded reason label (label cardinality
/// must stay fixed no matter what reason strings errors carry).
pub fn reject_reason_label(code: u16) -> &'static str {
    match code {
        429 => "overload",
        503 => "drain",
        _ => "other",
    }
}

/// Parse a compiled plan's run-length summary (`"40D 10C"`, the
/// [`crate::guidance::GuidancePlan::summary`] format) into one
/// `plan_exec{mode,steps,evals}` event per segment. Dual segments cost
/// 2 UNet evals per step; every other mode costs 1.
pub fn plan_exec_events(summary: &str) -> Vec<TraceEvent> {
    let mut out = Vec::new();
    for token in summary.split_whitespace() {
        let Some(mode) = token.chars().last() else { continue };
        let Ok(steps) = token[..token.len() - mode.len_utf8()].parse::<usize>() else {
            continue;
        };
        let per_step = if mode == 'D' { 2 } else { 1 };
        out.push(TraceEvent::PlanExec { mode, steps, evals: steps * per_step });
    }
    out
}

// ---------------------------------------------------------------------------
// Per-layer handle bundles
// ---------------------------------------------------------------------------

/// Engine-layer metrics: eval counts and per-phase time totals from
/// `begin` / `step_batch` / `finish` (attached via
/// [`crate::engine::Engine::attach_telemetry`]).
pub struct EngineMetrics {
    enabled: bool,
    begun: Counter,
    finished: Counter,
    iterations: Counter,
    evals_dual: Counter,
    evals_single: Counter,
    cond_ns: Counter,
    uncond_ns: Counter,
    combine_ns: Counter,
    scheduler_ns: Counter,
}

impl EngineMetrics {
    pub fn new(t: &Arc<Telemetry>) -> EngineMetrics {
        let r = t.registry();
        EngineMetrics {
            enabled: t.is_enabled(),
            begun: r.counter("sg_engine_samples_begun_total", "Samples begun", &[]),
            finished: r.counter("sg_engine_samples_finished_total", "Samples finished", &[]),
            iterations: r.counter(
                "sg_engine_iterations_total",
                "step_batch iterations that advanced at least one sample",
                &[],
            ),
            evals_dual: r.counter(
                "sg_engine_unet_evals_total",
                "UNet executions by guidance mode",
                &[("mode", "dual")],
            ),
            evals_single: r.counter(
                "sg_engine_unet_evals_total",
                "UNet executions by guidance mode",
                &[("mode", "single")],
            ),
            cond_ns: r.counter(
                "sg_engine_phase_ns_total",
                "Cumulative loop time by phase (nanoseconds)",
                &[("phase", "unet_cond")],
            ),
            uncond_ns: r.counter(
                "sg_engine_phase_ns_total",
                "Cumulative loop time by phase (nanoseconds)",
                &[("phase", "unet_uncond")],
            ),
            combine_ns: r.counter(
                "sg_engine_phase_ns_total",
                "Cumulative loop time by phase (nanoseconds)",
                &[("phase", "combine")],
            ),
            scheduler_ns: r.counter(
                "sg_engine_phase_ns_total",
                "Cumulative loop time by phase (nanoseconds)",
                &[("phase", "scheduler")],
            ),
        }
    }

    pub fn on_begin(&self) {
        if self.enabled {
            self.begun.inc();
        }
    }

    pub fn on_finish(&self) {
        if self.enabled {
            self.finished.inc();
        }
    }

    /// One `step_batch` iteration: `dual_evals` second-pass executions,
    /// `single_evals` single-pass executions, plus the iteration's phase
    /// time breakdown.
    pub fn on_step(&self, bd: &StepBreakdown, dual_evals: usize, single_evals: usize) {
        if !self.enabled {
            return;
        }
        self.iterations.inc();
        self.evals_dual.add(dual_evals as u64);
        self.evals_single.add(single_evals as u64);
        self.cond_ns.add((bd.unet_cond_ms * 1e6) as u64);
        self.uncond_ns.add((bd.unet_uncond_ms * 1e6) as u64);
        self.combine_ns.add((bd.combine_ms * 1e6) as u64);
        self.scheduler_ns.add((bd.scheduler_ms * 1e6) as u64);
    }
}

/// Continuous-batcher metrics: slot occupancy gauge + join/retire
/// counters (one bundle per batcher, labeled by scope).
#[derive(Clone)]
pub struct BatcherMetrics {
    enabled: bool,
    committed: Gauge,
    in_flight: Gauge,
    joins: Counter,
    retires: Counter,
    iterations: Counter,
    slots_used: Counter,
}

impl BatcherMetrics {
    pub fn new(t: &Arc<Telemetry>, scope: &str) -> BatcherMetrics {
        let r = t.registry();
        let l = [("scope", scope)];
        BatcherMetrics {
            enabled: t.is_enabled(),
            committed: r.gauge(
                "sg_batcher_slots_committed",
                "Peak-cost slots committed by the in-flight cohort",
                &l,
            ),
            in_flight: r.gauge("sg_batcher_in_flight", "Samples in the cohort", &l),
            joins: r.counter("sg_batcher_joins_total", "Samples admitted into cohorts", &l),
            retires: r.counter("sg_batcher_retires_total", "Samples retired from cohorts", &l),
            iterations: r.counter("sg_batcher_iterations_total", "Cohort iterations", &l),
            slots_used: r.counter(
                "sg_batcher_slots_used_total",
                "UNet slots executed across all iterations",
                &l,
            ),
        }
    }

    pub fn on_join(&self, committed_slots: usize, in_flight: usize) {
        if !self.enabled {
            return;
        }
        self.joins.inc();
        self.committed.set_usize(committed_slots);
        self.in_flight.set_usize(in_flight);
    }

    pub fn on_step(&self, slots_used: usize, retired: usize, committed: usize, in_flight: usize) {
        if !self.enabled {
            return;
        }
        self.iterations.inc();
        self.slots_used.add(slots_used as u64);
        self.retires.add(retired as u64);
        self.committed.set_usize(committed);
        self.in_flight.set_usize(in_flight);
    }
}

/// Coordinator-layer sink: request lifecycle counters, queue depth,
/// latency histogram, and the trace events the coordinator owns.
///
/// `owns_terminal` decides whether this sink may close spans: true for
/// a standalone coordinator, false for cluster replica coordinators
/// (the relay owns terminals there — see the module docs).
pub struct CoordSink {
    t: Arc<Telemetry>,
    enabled: bool,
    owns_terminal: bool,
    submitted: Counter,
    admitted: Counter,
    rejected: Counter,
    retired: Counter,
    expired: Counter,
    cancelled: Counter,
    cache_hits: Counter,
    dedup_joins: Counter,
    queue_depth: Gauge,
    latency_ms: Histogram,
    /// Measured-cost bundle, attached when the coordinator runs with a
    /// calibrated table (DESIGN.md §15).
    cost: Option<CostMetrics>,
    /// Frontier-planner bundle, attached when the coordinator runs with
    /// a compiled [`PlanSearch`] (DESIGN.md §16).
    planner: Option<PlannerMetrics>,
    scope: String,
}

impl CoordSink {
    pub fn new(t: &Arc<Telemetry>, scope: &str, owns_terminal: bool) -> CoordSink {
        let r = t.registry();
        let l = [("scope", scope)];
        CoordSink {
            enabled: t.is_enabled(),
            owns_terminal,
            submitted: r.counter("sg_coord_submitted_total", "Requests submitted", &l),
            admitted: r.counter("sg_coord_admitted_total", "Requests admitted", &l),
            rejected: r.counter("sg_coord_rejected_total", "Requests rejected at admission", &l),
            retired: r.counter("sg_coord_retired_total", "Requests completed", &l),
            expired: r.counter("sg_coord_expired_total", "Requests expired past deadline", &l),
            cancelled: r.counter(
                "sg_coord_cancelled_total",
                "Requests cancelled mid-flight by the client",
                &l,
            ),
            cache_hits: r.counter(
                "sg_cache_hits_total",
                "Requests served bit-exactly from the request cache",
                &l,
            ),
            dedup_joins: r.counter(
                "sg_cache_dedup_joins_total",
                "Requests coalesced onto an identical in-flight generation",
                &l,
            ),
            queue_depth: r.gauge("sg_coord_queue_depth", "Jobs queued or in flight", &l),
            latency_ms: r.histogram(
                "sg_request_latency_ms",
                "End-to-end request latency (milliseconds)",
                &l,
            ),
            cost: None,
            planner: None,
            scope: scope.to_string(),
            t: Arc::clone(t),
        }
    }

    /// Install the measured-cost bundle: retired plans are priced into
    /// the `sg_step_cost_ms` histograms against this table.
    pub fn attach_cost(&mut self, table: Arc<CostTable>) {
        self.cost = Some(CostMetrics::new(&self.t, table));
    }

    /// Install the frontier-planner bundle: the search's counters are
    /// mirrored into `sg_planner_*_total` on every admission/retire.
    pub fn attach_planner(&mut self, search: Arc<PlanSearch>) {
        self.planner = Some(PlannerMetrics::new(&self.t, search));
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.t
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn owns_terminal(&self) -> bool {
        self.owns_terminal
    }

    /// The `scope` label this sink stamps on its metric families.
    pub fn scope(&self) -> &str {
        &self.scope
    }

    pub fn begin_trace(&self) -> Option<TraceId> {
        if !self.enabled {
            return None;
        }
        self.t.begin_trace()
    }

    pub fn on_submitted(&self) {
        if self.enabled {
            self.submitted.inc();
        }
    }

    pub fn on_queue_depth(&self, depth: usize) {
        if self.enabled {
            self.queue_depth.set_usize(depth);
        }
    }

    /// Admission into this coordinator's queue. The span-level
    /// `admitted` event belongs to whichever layer decided admission:
    /// a replica sink (owns_terminal = false) sits behind a cluster
    /// front door that already recorded it, so it only appends the
    /// per-leg `queued` event.
    pub fn on_admitted(&self, trace: Option<TraceId>, class: &'static str, depth: usize) {
        if !self.enabled {
            return;
        }
        self.admitted.inc();
        self.queue_depth.set_usize(depth);
        if let Some(p) = &self.planner {
            p.refresh();
        }
        if self.owns_terminal {
            self.t.event(trace, TraceEvent::Admitted { class });
        }
        self.t.event(trace, TraceEvent::Queued { depth });
    }

    /// Admission rejection. The trace event is terminal, so replica
    /// sinks (owns_terminal = false) only count it — the cluster layer
    /// records the span-closing event.
    pub fn on_rejected(&self, trace: Option<TraceId>, code: u16, reason: &str) {
        if !self.enabled {
            return;
        }
        self.rejected.inc();
        let shed = self.t.registry().counter(
            "sg_coord_shed_total",
            "Requests shed, by reason",
            &[("scope", &self.scope), ("reason", reject_reason_label(code))],
        );
        shed.inc();
        if self.owns_terminal {
            self.t
                .event(trace, TraceEvent::Rejected { code, reason: reason.to_string() });
        }
    }

    pub fn on_cohort_join(&self, trace: Option<TraceId>, cohort: usize) {
        if self.enabled {
            self.t.event(trace, TraceEvent::CohortJoin { cohort });
        }
    }

    /// Exact-match request-cache hit (non-terminal: the hit path still
    /// records `on_retired`, which closes the span).
    pub fn on_cache_hit(&self, trace: Option<TraceId>) {
        if self.enabled {
            self.cache_hits.inc();
            self.t.event(trace, TraceEvent::CacheHit);
        }
    }

    /// Dedup coalescing (non-terminal: the span closes when the primary
    /// generation's fan-out delivers to this waiter).
    pub fn on_dedup_join(&self, trace: Option<TraceId>) {
        if self.enabled {
            self.dedup_joins.inc();
            self.t.event(trace, TraceEvent::DedupJoin);
        }
    }

    /// Successful completion: per-segment `plan_exec` events (execution
    /// happened on this coordinator either way), latency observation,
    /// and — when this sink owns terminals — the closing `retired`.
    pub fn on_retired(&self, trace: Option<TraceId>, plan_summary: &str, latency_ms: f64) {
        if !self.enabled {
            return;
        }
        self.retired.inc();
        self.latency_ms.observe_ms(latency_ms);
        if let Some(c) = &self.cost {
            c.on_plan(plan_summary);
        }
        if let Some(p) = &self.planner {
            p.refresh();
        }
        if trace.is_some() {
            for ev in plan_exec_events(plan_summary) {
                self.t.event(trace, ev);
            }
        }
        if self.owns_terminal {
            self.t.event(trace, TraceEvent::Retired);
        }
    }

    pub fn on_expired(&self, trace: Option<TraceId>) {
        if !self.enabled {
            return;
        }
        self.expired.inc();
        if self.owns_terminal {
            self.t.event(trace, TraceEvent::Expired);
        }
    }

    pub fn on_shed(&self, trace: Option<TraceId>, reason: &str) {
        if !self.enabled {
            return;
        }
        let shed = self.t.registry().counter(
            "sg_coord_shed_total",
            "Requests shed, by reason",
            &[("scope", &self.scope), ("reason", reason)],
        );
        shed.inc();
        if self.owns_terminal {
            self.t.event(trace, TraceEvent::Shed { reason: reason.to_string() });
        }
    }

    /// Client-initiated mid-flight cancel: counted on every sink, but —
    /// like the other terminals — the span-closing `cancelled` event
    /// belongs to the terminal owner only.
    pub fn on_cancelled(&self, trace: Option<TraceId>) {
        if !self.enabled {
            return;
        }
        self.cancelled.inc();
        if self.owns_terminal {
            self.t.event(trace, TraceEvent::Cancelled);
        }
    }
}

/// Measured-cost telemetry (DESIGN.md §15): per-step measured price by
/// (mode, resolution), fallback-pricing events, and the measured-vs-
/// analytic model ratio. Attached to a [`CoordSink`] when the
/// coordinator runs with a calibrated [`CostTable`]: every retired
/// plan's segments are priced into `sg_step_cost_ms` (one observation
/// per step, at the table's batch-1 price), and the table's internal
/// fallback counter is mirrored as the monotone Prometheus counter
/// `sg_cost_fallback_total`.
pub struct CostMetrics {
    enabled: bool,
    table: Arc<CostTable>,
    dual_ms: Histogram,
    single_ms: Histogram,
    fallbacks: Counter,
    model_ratio: Gauge,
    /// Last table fallback count mirrored into the registry (the
    /// registry counter is add-only, so we track the delta source).
    seen_fallbacks: AtomicU64,
}

impl CostMetrics {
    pub fn new(t: &Arc<Telemetry>, table: Arc<CostTable>) -> CostMetrics {
        let r = t.registry();
        let res = table.resolution().to_string();
        let m = CostMetrics {
            enabled: t.is_enabled(),
            dual_ms: r.histogram(
                "sg_step_cost_ms",
                "Measured per-step cost (milliseconds)",
                &[("mode", "dual"), ("resolution", res.as_str())],
            ),
            single_ms: r.histogram(
                "sg_step_cost_ms",
                "Measured per-step cost (milliseconds)",
                &[("mode", "single"), ("resolution", res.as_str())],
            ),
            fallbacks: r.counter(
                "sg_cost_fallback_total",
                "Step pricings that fell back to the analytic unit",
                &[],
            ),
            model_ratio: r.gauge(
                "sg_cost_model_ratio",
                "Measured batch-1 dual-step cost over the analytic 2-unit price",
                &[],
            ),
            table,
            seen_fallbacks: AtomicU64::new(0),
        };
        m.refresh();
        m
    }

    /// Price a retired plan's segments into the step-cost histograms.
    /// `plan_summary` is the [`crate::guidance::GuidancePlan::summary`]
    /// run-length format; `D` segments price at the dual rate, every
    /// other mode runs a single UNet pass.
    pub fn on_plan(&self, plan_summary: &str) {
        if !self.enabled {
            return;
        }
        for ev in plan_exec_events(plan_summary) {
            if let TraceEvent::PlanExec { mode, steps, .. } = ev {
                let (h, price) = if mode == 'D' {
                    (&self.dual_ms, self.table.sample_step_ms(StepMode::Dual))
                } else {
                    (&self.single_ms, self.table.sample_step_ms(StepMode::Single))
                };
                for _ in 0..steps {
                    h.observe_ms(price);
                }
            }
        }
        self.refresh();
    }

    /// Mirror the table's fallback counter (as a monotone delta) and the
    /// model-ratio gauge into the registry.
    pub fn refresh(&self) {
        if !self.enabled {
            return;
        }
        let now = self.table.fallback_count();
        let prev = self.seen_fallbacks.swap(now, Ordering::Relaxed);
        self.fallbacks.add(now.saturating_sub(prev));
        self.model_ratio.set(self.table.model_ratio());
    }
}

/// Frontier-planner telemetry (DESIGN.md §16): the [`PlanSearch`]'s
/// internal counters mirrored as monotone Prometheus counters. Attached
/// to a [`CoordSink`] when the coordinator runs with a compiled
/// frontier; refreshed on every admission and retire, mirroring
/// [`CostMetrics`]'s shared-counter discipline.
pub struct PlannerMetrics {
    enabled: bool,
    search: Arc<PlanSearch>,
    searches: Counter,
    fallbacks: Counter,
    floor_clamps: Counter,
    /// Last mirrored values (registry counters are add-only, so the
    /// shared snapshot is folded in as deltas).
    seen_searches: AtomicU64,
    seen_fallbacks: AtomicU64,
    seen_floor_clamps: AtomicU64,
}

impl PlannerMetrics {
    pub fn new(t: &Arc<Telemetry>, search: Arc<PlanSearch>) -> PlannerMetrics {
        let r = t.registry();
        let m = PlannerMetrics {
            enabled: t.is_enabled(),
            searches: r.counter(
                "sg_planner_search_total",
                "Admission-time frontier plan searches",
                &[],
            ),
            fallbacks: r.counter(
                "sg_planner_fallback_total",
                "Searches that missed every tuned bucket and fell back to analytic widening",
                &[],
            ),
            floor_clamps: r.counter(
                "sg_planner_floor_clamp_total",
                "Searches whose demanded saving was clamped to the quality floor",
                &[],
            ),
            search,
            seen_searches: AtomicU64::new(0),
            seen_fallbacks: AtomicU64::new(0),
            seen_floor_clamps: AtomicU64::new(0),
        };
        m.refresh();
        m
    }

    /// Mirror the search's counters into the registry as monotone deltas.
    pub fn refresh(&self) {
        if !self.enabled {
            return;
        }
        let snap = self.search.snapshot();
        let prev = self.seen_searches.swap(snap.searches, Ordering::Relaxed);
        self.searches.add(snap.searches.saturating_sub(prev));
        let prev = self.seen_fallbacks.swap(snap.fallbacks, Ordering::Relaxed);
        self.fallbacks.add(snap.fallbacks.saturating_sub(prev));
        let prev = self.seen_floor_clamps.swap(snap.floor_clamps, Ordering::Relaxed);
        self.floor_clamps.add(snap.floor_clamps.saturating_sub(prev));
    }
}

/// QoS-layer telemetry: admission counters by class, shed reasons,
/// queue depth + actuator position gauges, and the `actuator_rewrite`
/// trace event.
pub struct QosTelemetry {
    t: Arc<Telemetry>,
    enabled: bool,
    queue_depth: Gauge,
    actuator: Gauge,
    deadline_missed: Counter,
}

impl QosTelemetry {
    pub fn new(t: &Arc<Telemetry>) -> QosTelemetry {
        let r = t.registry();
        QosTelemetry {
            enabled: t.is_enabled(),
            queue_depth: r.gauge("sg_qos_queue_depth", "Queue depth seen at admission", &[]),
            actuator: r.gauge(
                "sg_qos_actuator_fraction",
                "Last shed fraction applied by the actuator",
                &[],
            ),
            deadline_missed: r.counter(
                "sg_qos_deadline_missed_total",
                "Requests that missed their deadline after admission",
                &[],
            ),
            t: Arc::clone(t),
        }
    }

    pub fn on_admitted(&self, class: &'static str, depth: usize) {
        if !self.enabled {
            return;
        }
        self.queue_depth.set_usize(depth);
        self.t
            .registry()
            .counter("sg_qos_admitted_total", "Admissions by class", &[("class", class)])
            .inc();
    }

    pub fn on_rejected(&self, class: &'static str, code: u16) {
        if !self.enabled {
            return;
        }
        self.t
            .registry()
            .counter(
                "sg_qos_rejected_total",
                "Rejections by class and reason",
                &[("class", class), ("reason", reject_reason_label(code))],
            )
            .inc();
    }

    /// Actuator applied `to` (possibly == the request's own `from`):
    /// records the gauge always, the trace event only on a real rewrite.
    pub fn on_actuator(&self, trace: Option<TraceId>, from: f64, to: f64) {
        if !self.enabled {
            return;
        }
        self.actuator.set(to);
        if (from - to).abs() > 1e-12 {
            self.t.event(trace, TraceEvent::ActuatorRewrite { from, to });
        }
    }

    pub fn on_deadline_miss(&self) {
        if self.enabled {
            self.deadline_missed.inc();
        }
    }

    /// Frontier plan search applied a Pareto point to this admission:
    /// record the selected point's predicted quality and priced cost on
    /// the request's span (DESIGN.md §16).
    pub fn on_plan_search(&self, trace: Option<TraceId>, ssim: f64, cost_ms: f64) {
        if self.enabled {
            self.t.event(trace, TraceEvent::PlanSearched { ssim, cost_ms });
        }
    }
}

/// Cluster-layer telemetry: per-replica routing/health/outstanding-eval
/// series, requeue/ejection counters, cluster-level latency, and the
/// relay-owned terminal trace events.
pub struct ClusterMetrics {
    t: Arc<Telemetry>,
    enabled: bool,
    routed: Vec<Counter>,
    outstanding: Vec<Gauge>,
    healthy: Vec<Gauge>,
    requeued: Counter,
    ejected: Counter,
    latency_ms: Histogram,
}

impl ClusterMetrics {
    pub fn new(t: &Arc<Telemetry>, replicas: usize) -> ClusterMetrics {
        let r = t.registry();
        let mut routed = Vec::with_capacity(replicas);
        let mut outstanding = Vec::with_capacity(replicas);
        let mut healthy = Vec::with_capacity(replicas);
        for i in 0..replicas {
            let id = i.to_string();
            let l = [("replica", id.as_str())];
            routed.push(r.counter("sg_cluster_routed_total", "Requests routed, by replica", &l));
            outstanding.push(r.gauge(
                "sg_cluster_outstanding_evals",
                "Plan-cost UNet evals outstanding, by replica",
                &l,
            ));
            let h = r.gauge("sg_cluster_healthy", "Replica health (1 healthy, 0 ejected)", &l);
            h.set(1.0);
            healthy.push(h);
        }
        ClusterMetrics {
            enabled: t.is_enabled(),
            routed,
            outstanding,
            healthy,
            requeued: r.counter("sg_cluster_requeued_total", "Failover requeues", &[]),
            ejected: r.counter("sg_cluster_ejected_total", "Replicas ejected", &[]),
            latency_ms: r.histogram(
                "sg_cluster_latency_ms",
                "Cluster end-to-end latency (milliseconds)",
                &[],
            ),
            t: Arc::clone(t),
        }
    }

    pub fn telemetry(&self) -> &Arc<Telemetry> {
        &self.t
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn begin_trace(&self) -> Option<TraceId> {
        if !self.enabled {
            return None;
        }
        self.t.begin_trace()
    }

    pub fn on_admitted(&self, trace: Option<TraceId>, class: &'static str, depth: usize) {
        if !self.enabled {
            return;
        }
        self.t.event(trace, TraceEvent::Admitted { class });
        self.t.event(trace, TraceEvent::Queued { depth });
    }

    pub fn on_rejected(&self, trace: Option<TraceId>, code: u16, reason: &str) {
        if self.enabled {
            self.t
                .event(trace, TraceEvent::Rejected { code, reason: reason.to_string() });
        }
    }

    /// A placement: `requeued_from = Some(f)` marks a failover leg
    /// (`requeued{from,to}`), None a first placement (`routed{replica}`).
    pub fn on_placed(
        &self,
        trace: Option<TraceId>,
        replica: usize,
        outstanding_evals: u64,
        requeued_from: Option<usize>,
    ) {
        if !self.enabled {
            return;
        }
        if let Some(c) = self.routed.get(replica) {
            c.inc();
        }
        if let Some(g) = self.outstanding.get(replica) {
            g.set(outstanding_evals as f64);
        }
        match requeued_from {
            Some(from) => {
                self.requeued.inc();
                self.t.event(trace, TraceEvent::Requeued { from, to: replica });
            }
            None => self.t.event(trace, TraceEvent::Routed { replica }),
        }
    }

    pub fn on_outstanding(&self, replica: usize, outstanding_evals: u64) {
        if !self.enabled {
            return;
        }
        if let Some(g) = self.outstanding.get(replica) {
            g.set(outstanding_evals as f64);
        }
    }

    pub fn on_ejected(&self, replica: usize) {
        if !self.enabled {
            return;
        }
        self.ejected.inc();
        if let Some(g) = self.healthy.get(replica) {
            g.set(0.0);
        }
    }

    pub fn on_retired(&self, trace: Option<TraceId>, latency_ms: f64) {
        if !self.enabled {
            return;
        }
        self.latency_ms.observe_ms(latency_ms);
        self.t.event(trace, TraceEvent::Retired);
    }

    pub fn on_expired(&self, trace: Option<TraceId>) {
        if self.enabled {
            self.t.event(trace, TraceEvent::Expired);
        }
    }

    pub fn on_shed(&self, trace: Option<TraceId>, reason: &str) {
        if self.enabled {
            self.t.event(trace, TraceEvent::Shed { reason: reason.to_string() });
        }
    }

    /// Relay-owned terminal for a client-cancelled request.
    pub fn on_cancelled(&self, trace: Option<TraceId>) {
        if !self.enabled {
            return;
        }
        self.t
            .registry()
            .counter("sg_cluster_cancelled_total", "Requests cancelled mid-flight", &[])
            .inc();
        self.t.event(trace, TraceEvent::Cancelled);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_telemetry_is_inert() {
        let t = Telemetry::off();
        assert!(!t.is_enabled());
        assert!(t.begin_trace().is_none());
        t.event(Some(1), TraceEvent::Retired);
        assert!(t.traces().is_empty());
        let sink = CoordSink::new(&t, "single", true);
        sink.on_submitted();
        sink.on_admitted(None, "standard", 1);
        assert_eq!(t.render_prometheus().lines().count(), 0, "no samples when disabled");
    }

    #[test]
    fn plan_summary_parses_to_segments() {
        let evs = plan_exec_events("40D 10C");
        assert_eq!(
            evs,
            vec![
                TraceEvent::PlanExec { mode: 'D', steps: 40, evals: 80 },
                TraceEvent::PlanExec { mode: 'C', steps: 10, evals: 10 },
            ]
        );
        let evs = plan_exec_events("1D 2C 3R");
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[2], TraceEvent::PlanExec { mode: 'R', steps: 3, evals: 3 });
        assert!(plan_exec_events("").is_empty());
    }

    #[test]
    fn coord_sink_records_lifecycle() {
        let t = Telemetry::with_clock(16, Clock::manual());
        let sink = CoordSink::new(&t, "single", true);
        let trace = sink.begin_trace();
        assert!(trace.is_some());
        sink.on_submitted();
        sink.on_admitted(trace, "interactive", 2);
        t.clock().advance_ms(5.0);
        sink.on_cohort_join(trace, 3);
        sink.on_retired(trace, "2D 2C", 5.0);
        let span = t.traces().span(trace.unwrap()).unwrap();
        assert_eq!(span.terminal_events(), 1);
        assert!(span.has("cohort_join"));
        assert!(span.has("plan_exec"));
        // manual clock: the retire events sit exactly at 5 ms
        assert_eq!(span.events.last().unwrap().at_ns, 5_000_000);
        let text = t.render_prometheus();
        assert!(text.contains("sg_coord_retired_total{scope=\"single\"} 1"));
        assert!(text.contains("sg_request_latency_ms_bucket{scope=\"single\",le=\"+Inf\"} 1"));
    }

    #[test]
    fn replica_sink_never_closes_spans() {
        let t = Telemetry::with_clock(16, Clock::manual());
        let sink = CoordSink::new(&t, "replica0", false);
        let trace = t.begin_trace();
        sink.on_retired(trace, "4D", 1.0);
        sink.on_expired(trace);
        sink.on_shed(trace, "drain");
        sink.on_cancelled(trace);
        sink.on_rejected(trace, 503, "draining");
        let span = t.traces().span(trace.unwrap()).unwrap();
        assert_eq!(span.terminal_events(), 0, "replica sinks must not close spans");
        assert!(span.has("plan_exec"));
    }

    #[test]
    fn cost_metrics_price_retired_plans() {
        let t = Telemetry::with_clock(16, Clock::manual());
        let mut sink = CoordSink::new(&t, "single", true);
        let table = Arc::new(CostTable::proportional(2.5, &[1]));
        sink.attach_cost(Arc::clone(&table));
        sink.on_retired(None, "4D 6C", 20.0);
        let text = t.render_prometheus();
        // 4 dual steps at 5 ms, 6 single steps at 2.5 ms
        assert!(
            text.contains("sg_step_cost_ms_count{mode=\"dual\",resolution=\"0\"} 4"),
            "{text}"
        );
        assert!(text.contains("sg_step_cost_ms_count{mode=\"single\",resolution=\"0\"} 6"));
        assert!(text.contains("sg_cost_fallback_total 0"));
        // a proportional table measures exactly the analytic model
        assert!(text.contains("sg_cost_model_ratio 1"));
        // a pricing miss on the table surfaces at the next refresh
        let _ = table.step_ms(64, StepMode::Dual);
        sink.on_retired(None, "1D", 1.0);
        let text = t.render_prometheus();
        assert!(text.contains("sg_cost_fallback_total 1"), "{text}");
    }

    #[test]
    fn planner_metrics_mirror_search_counters() {
        use crate::guidance::{
            FrontierBucket, FrontierManifest, FrontierPoint, GuidanceSchedule, GuidanceStrategy,
            WindowSpec,
        };
        let bucket = FrontierBucket {
            steps: 50,
            full_cost_ms: 100.0,
            points: vec![
                FrontierPoint {
                    label: "floor".into(),
                    schedule: GuidanceSchedule::Window(WindowSpec::last(0.5)),
                    strategy: GuidanceStrategy::CondOnly,
                    ssim: 0.9,
                    cost_ms: 75.0,
                },
                FrontierPoint {
                    label: "full".into(),
                    schedule: GuidanceSchedule::none(),
                    strategy: GuidanceStrategy::CondOnly,
                    ssim: 1.0,
                    cost_ms: 100.0,
                },
            ],
        };
        let manifest = FrontierManifest::seal("t", "synthetic", "p", "fp", 8, 7.5, 2, vec![bucket]);
        let search = Arc::new(PlanSearch::new(manifest).unwrap());
        let t = Telemetry::with_clock(16, Clock::manual());
        let mut sink = CoordSink::new(&t, "single", true);
        sink.attach_planner(Arc::clone(&search));
        // one hit, one bucket miss, one floor clamp on the shared search
        search.select(50, 0.1, 0.5);
        search.select(500, 0.1, 0.5);
        search.select(50, 0.9, 0.5);
        sink.on_admitted(None, "standard", 1);
        let text = t.render_prometheus();
        assert!(text.contains("sg_planner_search_total 3"), "{text}");
        assert!(text.contains("sg_planner_fallback_total 1"), "{text}");
        assert!(text.contains("sg_planner_floor_clamp_total 1"), "{text}");
        // refreshes fold in deltas, never double-count
        sink.on_retired(None, "1D", 1.0);
        let text = t.render_prometheus();
        assert!(text.contains("sg_planner_search_total 3"), "{text}");
        assert!(text.contains("sg_planner_fallback_total 1"), "{text}");
    }

    #[test]
    fn cluster_failover_leg_events() {
        let t = Telemetry::with_clock(16, Clock::manual());
        let cm = ClusterMetrics::new(&t, 2);
        let trace = cm.begin_trace();
        cm.on_admitted(trace, "standard", 1);
        cm.on_placed(trace, 0, 24, None);
        cm.on_ejected(0);
        cm.on_placed(trace, 1, 24, Some(0));
        cm.on_retired(trace, 12.0);
        let span = t.traces().span(trace.unwrap()).unwrap();
        assert!(span.has("routed"));
        assert!(span.has("requeued"));
        assert_eq!(span.terminal_events(), 1);
        let text = t.render_prometheus();
        assert!(text.contains("sg_cluster_requeued_total 1"));
        assert!(text.contains("sg_cluster_healthy{replica=\"0\"} 0"));
        assert!(text.contains("sg_cluster_healthy{replica=\"1\"} 1"));
    }
}
