//! SSIM (structural similarity) on luma, 8x8 sliding windows.
//!
//! Standard Wang et al. 2004 formulation with C1/C2 stabilizers for an
//! 8-bit dynamic range, uniform (box) windows of 8x8 with stride 1, mean
//! over all windows. For the small images this stack generates, the box
//! window matches what torchmetrics' `ssim(..., gaussian_kernel=False)`
//! computes.

const C1: f64 = 6.5025; // (0.01 * 255)^2
const C2: f64 = 58.5225; // (0.03 * 255)^2
const WIN: usize = 8;

/// SSIM over luma planes (values in [0, 255]); `w` x `h` row-major.
///
/// Falls back to a single full-image window when the image is smaller
/// than 8x8.
pub fn ssim_luma(a: &[f32], b: &[f32], w: usize, h: usize) -> f64 {
    assert_eq!(a.len(), w * h, "ssim: plane size mismatch");
    assert_eq!(b.len(), w * h, "ssim: plane size mismatch");
    let win_w = WIN.min(w);
    let win_h = WIN.min(h);
    let mut total = 0.0f64;
    let mut count = 0usize;
    for y0 in 0..=(h - win_h) {
        for x0 in 0..=(w - win_w) {
            total += window_ssim(a, b, w, x0, y0, win_w, win_h);
            count += 1;
        }
    }
    total / count as f64
}

fn window_ssim(a: &[f32], b: &[f32], stride: usize, x0: usize, y0: usize, ww: usize, wh: usize) -> f64 {
    let n = (ww * wh) as f64;
    let (mut sa, mut sb, mut saa, mut sbb, mut sab) = (0.0f64, 0.0, 0.0, 0.0, 0.0);
    for y in y0..y0 + wh {
        let row = y * stride;
        for x in x0..x0 + ww {
            let va = a[row + x] as f64;
            let vb = b[row + x] as f64;
            sa += va;
            sb += vb;
            saa += va * va;
            sbb += vb * vb;
            sab += va * vb;
        }
    }
    let mu_a = sa / n;
    let mu_b = sb / n;
    let var_a = (saa / n - mu_a * mu_a).max(0.0);
    let var_b = (sbb / n - mu_b * mu_b).max(0.0);
    let cov = sab / n - mu_a * mu_b;
    ((2.0 * mu_a * mu_b + C1) * (2.0 * cov + C2))
        / ((mu_a * mu_a + mu_b * mu_b + C1) * (var_a + var_b + C2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn noise_plane(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.next_below(256) as f32).collect()
    }

    #[test]
    fn identical_images_ssim_one() {
        let a = noise_plane(0, 32 * 32);
        assert!((ssim_luma(&a, &a, 32, 32) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn independent_noise_low_ssim() {
        let a = noise_plane(1, 32 * 32);
        let b = noise_plane(2, 32 * 32);
        let s = ssim_luma(&a, &b, 32, 32);
        assert!(s < 0.2, "independent noise should have low SSIM, got {s}");
    }

    #[test]
    fn symmetric() {
        let a = noise_plane(3, 16 * 16);
        let b: Vec<f32> = a.iter().map(|v| (v * 0.9 + 10.0).min(255.0)).collect();
        let s1 = ssim_luma(&a, &b, 16, 16);
        let s2 = ssim_luma(&b, &a, 16, 16);
        assert!((s1 - s2).abs() < 1e-12);
    }

    #[test]
    fn bounded() {
        for seed in 0..5 {
            let a = noise_plane(seed, 16 * 16);
            let b = noise_plane(seed + 100, 16 * 16);
            let s = ssim_luma(&a, &b, 16, 16);
            assert!((-1.0..=1.0).contains(&s), "{s}");
        }
    }

    #[test]
    fn degrades_with_noise_amplitude() {
        let a = noise_plane(4, 32 * 32);
        let mut r = Rng::new(5);
        let small: Vec<f32> = a.iter().map(|v| (v + r.next_normal() as f32 * 2.0).clamp(0.0, 255.0)).collect();
        let big: Vec<f32> = a.iter().map(|v| (v + r.next_normal() as f32 * 40.0).clamp(0.0, 255.0)).collect();
        let s_small = ssim_luma(&a, &small, 32, 32);
        let s_big = ssim_luma(&a, &big, 32, 32);
        assert!(s_small > s_big, "{s_small} vs {s_big}");
        assert!(s_small > 0.9);
    }

    #[test]
    fn tiny_image_single_window() {
        let a = vec![100.0f32; 4 * 4];
        let b = vec![110.0f32; 4 * 4];
        let s = ssim_luma(&a, &b, 4, 4);
        assert!(s > 0.0 && s < 1.0);
    }

    #[test]
    fn luminance_shift_penalized() {
        let a = noise_plane(6, 16 * 16);
        let b: Vec<f32> = a.iter().map(|v| (v + 60.0).min(255.0)).collect();
        assert!(ssim_luma(&a, &b, 16, 16) < 0.95);
    }
}
