//! FID-lite: Fréchet distance between image-set feature distributions.
//!
//! The paper evaluates quality pair-wise with humans; a distribution-
//! level metric complements that when comparing *sets* of generations
//! (e.g. 60 baseline images vs 60 optimized images). True FID uses an
//! InceptionV3 embedding, unavailable offline — we substitute a
//! hand-crafted patch-statistics feature (per-patch luma mean/std over a
//! 4x4 grid + global gradient energy, 33 dims), fit Gaussians and compute
//! the exact Fréchet distance
//!
//! ```text
//! d² = ‖μ₁−μ₂‖² + tr(Σ₁ + Σ₂ − 2 (Σ₁^{1/2} Σ₂ Σ₁^{1/2})^{1/2})
//! ```
//!
//! with a Jacobi symmetric eigensolver (no linalg crates offline). The
//! *ranking* behaviour (more distortion → larger distance) is what the
//! benches rely on, mirroring how FID is used in the diffusion
//! literature.

use crate::image::RgbImage;

const GRID: usize = 4;
/// Feature dimension: GRID*GRID * (mean, std) + gradient energy.
pub const FEATURE_DIM: usize = GRID * GRID * 2 + 1;

/// Per-image feature vector (patch statistics).
pub fn image_features(img: &RgbImage) -> Vec<f64> {
    let luma = img.luma();
    let (w, h) = (img.width, img.height);
    let mut feat = Vec::with_capacity(FEATURE_DIM);
    let (pw, ph) = (w.div_ceil(GRID), h.div_ceil(GRID));
    for gy in 0..GRID {
        for gx in 0..GRID {
            let (x0, y0) = (gx * pw, gy * ph);
            let (x1, y1) = (((gx + 1) * pw).min(w), ((gy + 1) * ph).min(h));
            let mut n = 0.0f64;
            let (mut s, mut ss) = (0.0f64, 0.0f64);
            for y in y0..y1 {
                for x in x0..x1 {
                    let v = luma[y * w + x] as f64 / 255.0;
                    s += v;
                    ss += v * v;
                    n += 1.0;
                }
            }
            let mean = if n > 0.0 { s / n } else { 0.0 };
            let var = if n > 0.0 { (ss / n - mean * mean).max(0.0) } else { 0.0 };
            feat.push(mean);
            feat.push(var.sqrt());
        }
    }
    // global gradient energy (detail proxy)
    let mut ge = 0.0f64;
    for y in 0..h.saturating_sub(1) {
        for x in 0..w.saturating_sub(1) {
            let dx = (luma[y * w + x + 1] - luma[y * w + x]) as f64 / 255.0;
            let dy = (luma[(y + 1) * w + x] - luma[y * w + x]) as f64 / 255.0;
            ge += dx * dx + dy * dy;
        }
    }
    feat.push((ge / ((w * h) as f64)).sqrt());
    debug_assert_eq!(feat.len(), FEATURE_DIM);
    feat
}

/// Mean + covariance of a feature set.
#[derive(Debug, Clone)]
pub struct GaussianStats {
    pub mean: Vec<f64>,
    /// Row-major d x d covariance.
    pub cov: Vec<f64>,
    pub dim: usize,
}

impl GaussianStats {
    /// Fit from feature vectors (rows). Uses the biased (1/n) estimator,
    /// matching the standard FID implementation's `np.cov(..., rowvar=False)`
    /// up to the n/(n-1) factor which cancels in comparisons.
    pub fn fit(features: &[Vec<f64>]) -> GaussianStats {
        assert!(!features.is_empty());
        let d = features[0].len();
        let n = features.len() as f64;
        let mut mean = vec![0.0; d];
        for f in features {
            assert_eq!(f.len(), d);
            for (m, &v) in mean.iter_mut().zip(f) {
                *m += v;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        let mut cov = vec![0.0; d * d];
        for f in features {
            for i in 0..d {
                let di = f[i] - mean[i];
                for j in i..d {
                    cov[i * d + j] += di * (f[j] - mean[j]);
                }
            }
        }
        for i in 0..d {
            for j in i..d {
                let v = cov[i * d + j] / n;
                cov[i * d + j] = v;
                cov[j * d + i] = v;
            }
        }
        GaussianStats { mean, cov, dim: d }
    }
}

// ---------------------------------------------------------------------------
// small symmetric linear algebra (Jacobi)
// ---------------------------------------------------------------------------

fn matmul(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    let mut out = vec![0.0; d * d];
    for i in 0..d {
        for k in 0..d {
            let aik = a[i * d + k];
            if aik == 0.0 {
                continue;
            }
            for j in 0..d {
                out[i * d + j] += aik * b[k * d + j];
            }
        }
    }
    out
}

/// Jacobi eigendecomposition of a symmetric matrix. Returns
/// (eigenvalues, row-major eigenvector matrix V with rows = eigenvectors).
pub fn sym_eigen(mat: &[f64], d: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(mat.len(), d * d);
    let mut a = mat.to_vec();
    let mut v = vec![0.0; d * d];
    for i in 0..d {
        v[i * d + i] = 1.0;
    }
    for _sweep in 0..100 {
        // largest off-diagonal magnitude
        let mut off = 0.0f64;
        for i in 0..d {
            for j in (i + 1)..d {
                off = off.max(a[i * d + j].abs());
            }
        }
        if off < 1e-12 {
            break;
        }
        for p in 0..d {
            for q in (p + 1)..d {
                let apq = a[p * d + q];
                if apq.abs() < 1e-14 {
                    continue;
                }
                let app = a[p * d + p];
                let aqq = a[q * d + q];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of a
                for k in 0..d {
                    let akp = a[k * d + p];
                    let akq = a[k * d + q];
                    a[k * d + p] = c * akp - s * akq;
                    a[k * d + q] = s * akp + c * akq;
                }
                for k in 0..d {
                    let apk = a[p * d + k];
                    let aqk = a[q * d + k];
                    a[p * d + k] = c * apk - s * aqk;
                    a[q * d + k] = s * apk + c * aqk;
                }
                // accumulate eigenvectors (rows of v)
                for k in 0..d {
                    let vpk = v[p * d + k];
                    let vqk = v[q * d + k];
                    v[p * d + k] = c * vpk - s * vqk;
                    v[q * d + k] = s * vpk + c * vqk;
                }
            }
        }
    }
    let eig = (0..d).map(|i| a[i * d + i]).collect();
    (eig, v)
}

/// Symmetric PSD matrix square root via eigendecomposition.
pub fn sym_sqrt(mat: &[f64], d: usize) -> Vec<f64> {
    let (eig, v) = sym_eigen(mat, d);
    // sqrt = V^T diag(sqrt(max(eig,0))) V   (rows of V are eigenvectors)
    let mut out = vec![0.0; d * d];
    for (k, &lam) in eig.iter().enumerate() {
        let s = lam.max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..d {
            let vik = v[k * d + i];
            for j in 0..d {
                out[i * d + j] += s * vik * v[k * d + j];
            }
        }
    }
    out
}

/// Fréchet distance squared between two Gaussian fits.
pub fn frechet_distance(a: &GaussianStats, b: &GaussianStats) -> f64 {
    assert_eq!(a.dim, b.dim);
    let d = a.dim;
    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(&x, &y)| (x - y) * (x - y))
        .sum();
    // tr(S1 + S2 - 2 sqrt(S1^{1/2} S2 S1^{1/2}))
    let s1_sqrt = sym_sqrt(&a.cov, d);
    let inner = matmul(&matmul(&s1_sqrt, &b.cov, d), &s1_sqrt, d);
    let (eig, _) = sym_eigen(&inner, d);
    let tr_sqrt: f64 = eig.iter().map(|&l| l.max(0.0).sqrt()).sum();
    let tr1: f64 = (0..d).map(|i| a.cov[i * d + i]).sum();
    let tr2: f64 = (0..d).map(|i| b.cov[i * d + i]).sum();
    (mean_term + tr1 + tr2 - 2.0 * tr_sqrt).max(0.0)
}

/// Convenience: FID-lite between two image sets.
pub fn fid_lite(set_a: &[RgbImage], set_b: &[RgbImage]) -> f64 {
    let fa: Vec<Vec<f64>> = set_a.iter().map(image_features).collect();
    let fb: Vec<Vec<f64>> = set_b.iter().map(image_features).collect();
    frechet_distance(&GaussianStats::fit(&fa), &GaussianStats::fit(&fb))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn noise_img(seed: u64, w: usize, h: usize) -> RgbImage {
        let mut rng = Rng::new(seed);
        let mut img = RgbImage::new(w, h);
        for b in img.data.iter_mut() {
            *b = rng.next_below(256) as u8;
        }
        img
    }

    #[test]
    fn jacobi_diagonal_matrix() {
        let m = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (mut eig, _) = sym_eigen(&m, 3);
        eig.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 2.0).abs() < 1e-10);
        assert!((eig[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2, 1], [1, 2]] -> eigenvalues 1, 3
        let m = vec![2.0, 1.0, 1.0, 2.0];
        let (mut eig, v) = sym_eigen(&m, 2);
        eig.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!((eig[1] - 3.0).abs() < 1e-10);
        // eigenvectors orthonormal
        let dot = v[0] * v[2] + v[1] * v[3];
        assert!(dot.abs() < 1e-10);
    }

    #[test]
    fn sqrt_squares_back() {
        // random symmetric PSD: A = B B^T
        let mut rng = Rng::new(1);
        let d = 5;
        let b: Vec<f64> = (0..d * d).map(|_| rng.next_normal()).collect();
        let mut a = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..d {
                for k in 0..d {
                    a[i * d + j] += b[i * d + k] * b[j * d + k];
                }
            }
        }
        let r = sym_sqrt(&a, d);
        let rr = matmul(&r, &r, d);
        for (x, y) in rr.iter().zip(&a) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn frechet_identical_sets_zero() {
        let imgs: Vec<RgbImage> = (0..12).map(|i| noise_img(i, 32, 32)).collect();
        let d = fid_lite(&imgs, &imgs);
        assert!(d < 1e-9, "identical sets must have ~0 distance, got {d}");
    }

    #[test]
    fn frechet_closed_form_univariate() {
        // d=1 Gaussians: FID = (m1-m2)^2 + (s1-s2)^2
        let a = GaussianStats { mean: vec![1.0], cov: vec![4.0], dim: 1 };
        let b = GaussianStats { mean: vec![3.0], cov: vec![9.0], dim: 1 };
        let d = frechet_distance(&a, &b);
        let expect = (1.0f64 - 3.0).powi(2) + (2.0f64 - 3.0).powi(2);
        assert!((d - expect).abs() < 1e-9, "{d} vs {expect}");
    }

    #[test]
    fn frechet_symmetric() {
        let sa: Vec<Vec<f64>> = (0..20).map(|i| image_features(&noise_img(i, 16, 16))).collect();
        let sb: Vec<Vec<f64>> =
            (100..120).map(|i| image_features(&noise_img(i, 16, 16))).collect();
        let ga = GaussianStats::fit(&sa);
        let gb = GaussianStats::fit(&sb);
        let d1 = frechet_distance(&ga, &gb);
        let d2 = frechet_distance(&gb, &ga);
        assert!((d1 - d2).abs() < 1e-6 * (1.0 + d1.abs()), "{d1} vs {d2}");
    }

    #[test]
    fn frechet_monotone_in_distortion() {
        // distorting one set more must increase the distance
        let base: Vec<RgbImage> = (0..16).map(|i| noise_img(i, 32, 32)).collect();
        let distort = |amount: f64, seed_off: u64| -> Vec<RgbImage> {
            base.iter()
                .enumerate()
                .map(|(i, img)| {
                    let mut rng = Rng::new(1000 + seed_off + i as u64);
                    let mut out = img.clone();
                    for b in out.data.iter_mut() {
                        let v = *b as f64 + rng.next_normal() * amount;
                        *b = v.clamp(0.0, 255.0) as u8;
                    }
                    out
                })
                .collect()
        };
        let d_small = fid_lite(&base, &distort(5.0, 0));
        let d_big = fid_lite(&base, &distort(60.0, 1));
        assert!(
            d_big > d_small,
            "bigger distortion must raise FID-lite: {d_small} vs {d_big}"
        );
    }

    #[test]
    fn features_dimension_and_finiteness() {
        let f = image_features(&noise_img(0, 33, 17)); // non-divisible dims
        assert_eq!(f.len(), FEATURE_DIM);
        assert!(f.iter().all(|v| v.is_finite()));
        // all means in [0, 1]
        for i in (0..32).step_by(2) {
            assert!((0.0..=1.0).contains(&f[i]));
        }
    }
}
