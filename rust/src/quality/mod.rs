//! Image-quality metrics + the synthetic SBS judge.
//!
//! The paper evaluates quality with human raters (Figs 1–3). Offline we
//! quantify the same comparisons with standard full-reference metrics —
//! MSE / PSNR on pixels, SSIM on luma — plus latent-space distance, and
//! simulate the §3.2 side-by-side study with a threshold judge over SSIM.
//! The *shape* of the paper's findings (later windows hurt less; 20% is
//! below the perceptibility threshold) is what these reproduce.

mod fid;
mod sbs;
mod ssim;

pub use fid::{fid_lite, frechet_distance, image_features, GaussianStats, FEATURE_DIM};
pub use sbs::{SbsJudge, SbsOutcome, SbsTally};
pub use ssim::ssim_luma;

use crate::image::RgbImage;

/// Mean squared error between two equal-length f32 buffers.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "mse: length mismatch");
    assert!(!a.is_empty());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Root-mean-square error.
pub fn rmse(a: &[f32], b: &[f32]) -> f64 {
    mse(a, b).sqrt()
}

/// PSNR in dB for signals in a known dynamic range (peak value).
pub fn psnr_with_peak(a: &[f32], b: &[f32], peak: f64) -> f64 {
    let m = mse(a, b);
    if m == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (peak * peak / m).log10()
    }
}

/// PSNR between two 8-bit RGB images (peak 255).
pub fn psnr(a: &RgbImage, b: &RgbImage) -> f64 {
    assert_eq!((a.width, a.height), (b.width, b.height), "psnr: size mismatch");
    let fa: Vec<f32> = a.data.iter().map(|&v| v as f32).collect();
    let fb: Vec<f32> = b.data.iter().map(|&v| v as f32).collect();
    psnr_with_peak(&fa, &fb, 255.0)
}

/// SSIM between two RGB images (computed on BT.601 luma).
pub fn ssim(a: &RgbImage, b: &RgbImage) -> f64 {
    assert_eq!((a.width, a.height), (b.width, b.height), "ssim: size mismatch");
    ssim_luma(&a.luma(), &b.luma(), a.width, a.height)
}

/// Normalized latent distance: ||a-b|| / ||a|| — scale-free measure of
/// how far an optimized trajectory drifted from the baseline.
pub fn latent_drift(baseline: &[f32], other: &[f32]) -> f64 {
    assert_eq!(baseline.len(), other.len());
    let num: f64 = baseline
        .iter()
        .zip(other)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    let den: f64 = baseline.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if den == 0.0 {
        if num == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img_from(vals: &[u8], w: usize, h: usize) -> RgbImage {
        let mut img = RgbImage::new(w, h);
        img.data.copy_from_slice(vals);
        img
    }

    #[test]
    fn mse_identity_zero() {
        let a = [1.0f32, 2.0, 3.0];
        assert_eq!(mse(&a, &a), 0.0);
    }

    #[test]
    fn mse_known_value() {
        assert!((mse(&[0.0, 0.0], &[3.0, 4.0]) - 12.5).abs() < 1e-12);
        assert!((rmse(&[0.0], &[2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn psnr_identity_infinite() {
        let img = img_from(&[10, 20, 30], 1, 1);
        assert!(psnr(&img, &img).is_infinite());
    }

    #[test]
    fn psnr_monotone_in_error() {
        let a = img_from(&[100, 100, 100], 1, 1);
        let b = img_from(&[101, 101, 101], 1, 1);
        let c = img_from(&[120, 120, 120], 1, 1);
        assert!(psnr(&a, &b) > psnr(&a, &c));
    }

    #[test]
    fn psnr_known_value() {
        // uniform error of 1 LSB -> MSE 1 -> PSNR = 20*log10(255) ≈ 48.13dB
        let a = img_from(&[0, 0, 0, 0, 0, 0], 2, 1);
        let b = img_from(&[1, 1, 1, 1, 1, 1], 2, 1);
        assert!((psnr(&a, &b) - 48.1308).abs() < 1e-3);
    }

    #[test]
    fn latent_drift_properties() {
        let a = [1.0f32, 2.0, 2.0];
        assert_eq!(latent_drift(&a, &a), 0.0);
        let b = [2.0f32, 4.0, 4.0];
        assert!((latent_drift(&a, &b) - 1.0).abs() < 1e-12); // ||a-2a||/||a|| = 1
        assert_eq!(latent_drift(&[0.0], &[0.0]), 0.0);
        assert!(latent_drift(&[0.0], &[1.0]).is_infinite());
    }

    #[test]
    #[should_panic]
    fn mse_length_mismatch_panics() {
        mse(&[1.0], &[1.0, 2.0]);
    }
}
