//! Synthetic side-by-side (SBS) judge — the §3.2 study without humans.
//!
//! The paper showed 60 (baseline, optimized) pairs to six raters; 68% of
//! judgments were "similar", 21% preferred the baseline, 11% the
//! optimized image. We simulate a rater with a *JND-referenced* test:
//!
//! 1. Measure the pair distance `d = 1 - SSIM(base, opt)`.
//! 2. Measure a just-noticeable-difference proxy on the same baseline:
//!    `d_jnd = 1 - SSIM(base, base + ±2LSB uniform noise)` — a distortion
//!    that is imperceptible by construction.
//! 3. The pair is *perceptibly different* when `d > R · d_jnd`, with the
//!    tolerance `R` jittered per (rater, pair) in log space — rater
//!    variability.
//! 4. Perceptibly-different pairs are judged by a sharpness proxy (mean
//!    local variance). Sub-JND pairs are "similar" — except that a
//!    forced-choice rater sometimes expresses a random preference anyway
//!    (`p_noise_pref`, the paper's raters split 21/11 on images its text
//!    calls "almost no perceivable change").
//!
//! This is a *simulation* of the human study (repro band = 0; DESIGN.md
//! section 3) — the reproduced quantity is the shape: a dominant
//! "similar" mass at 20% optimization with a small, split remainder.

use crate::image::RgbImage;
use crate::quality::ssim;
use crate::rng::Rng;

/// One rater's verdict on one pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SbsOutcome {
    Similar,
    PreferBaseline,
    PreferOptimized,
}

/// Aggregated tallies over (pair, rater) judgments.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SbsTally {
    pub similar: usize,
    pub prefer_baseline: usize,
    pub prefer_optimized: usize,
}

impl SbsTally {
    pub fn total(&self) -> usize {
        self.similar + self.prefer_baseline + self.prefer_optimized
    }

    pub fn record(&mut self, o: SbsOutcome) {
        match o {
            SbsOutcome::Similar => self.similar += 1,
            SbsOutcome::PreferBaseline => self.prefer_baseline += 1,
            SbsOutcome::PreferOptimized => self.prefer_optimized += 1,
        }
    }

    pub fn pct_similar(&self) -> f64 {
        100.0 * self.similar as f64 / self.total().max(1) as f64
    }

    pub fn pct_baseline(&self) -> f64 {
        100.0 * self.prefer_baseline as f64 / self.total().max(1) as f64
    }

    pub fn pct_optimized(&self) -> f64 {
        100.0 * self.prefer_optimized as f64 / self.total().max(1) as f64
    }
}

/// The configured judge panel.
#[derive(Debug, Clone)]
pub struct SbsJudge {
    /// Pairs farther than `jnd_tolerance` JNDs apart are perceptibly
    /// different (before rater jitter).
    pub jnd_tolerance: f64,
    /// Std-dev of the per-(rater, pair) log-space jitter on the tolerance.
    pub rater_noise: f64,
    /// Probability a rater voices a random preference on a sub-JND pair
    /// (forced-choice noise; the paper's raters did this ~32% of the
    /// time).
    pub p_noise_pref: f64,
    /// Number of simulated raters (the paper used 6).
    pub num_raters: usize,
    /// RNG seed for rater jitter.
    pub seed: u64,
}

impl Default for SbsJudge {
    fn default() -> Self {
        SbsJudge {
            jnd_tolerance: 4.0,
            rater_noise: 0.5,
            p_noise_pref: 0.32,
            num_raters: 6,
            seed: 0,
        }
    }
}

/// Mean local variance (sharpness proxy) over 4x4 tiles of the luma.
fn sharpness(img: &RgbImage) -> f64 {
    let luma = img.luma();
    let (w, h) = (img.width, img.height);
    let t = 4usize.min(w).min(h);
    let mut total = 0.0;
    let mut count = 0usize;
    for y0 in (0..=h - t).step_by(t) {
        for x0 in (0..=w - t).step_by(t) {
            let n = (t * t) as f64;
            let (mut s, mut ss) = (0.0f64, 0.0f64);
            for y in y0..y0 + t {
                for x in x0..x0 + t {
                    let v = luma[y * w + x] as f64;
                    s += v;
                    ss += v * v;
                }
            }
            let mu = s / n;
            total += (ss / n - mu * mu).max(0.0);
            count += 1;
        }
    }
    total / count as f64
}

/// The ±2LSB-noise JND proxy distance for a baseline image.
fn jnd_distance(base: &RgbImage, seed: u64) -> f64 {
    let mut rng = Rng::for_stream(seed, 0x4a4e44); // "JND"
    let mut distorted = base.clone();
    for b in distorted.data.iter_mut() {
        let delta = rng.next_below(5) as i16 - 2; // -2..=2 LSB
        *b = (*b as i16 + delta).clamp(0, 255) as u8;
    }
    (1.0 - ssim(base, &distorted)).max(1e-12)
}

impl SbsJudge {
    /// One rater's judgment of one pair.
    pub fn judge_one(
        &self,
        baseline: &RgbImage,
        optimized: &RgbImage,
        rater: usize,
        pair: usize,
    ) -> SbsOutcome {
        let d_pair = 1.0 - ssim(baseline, optimized);
        let d_jnd = jnd_distance(baseline, self.seed ^ pair as u64);
        let mut rng = Rng::for_stream(self.seed, ((rater as u64) << 32) | pair as u64);
        let tolerance = self.jnd_tolerance * (self.rater_noise * rng.next_normal()).exp();
        let prefer_by_sharpness = |rng: &mut Rng| {
            // sharpness difference below measurement noise -> coin flip
            let (sb, so) = (sharpness(baseline), sharpness(optimized));
            let rel = (sb - so) / (sb + so).max(1e-9);
            if rel.abs() < 0.002 {
                if rng.next_f64() < 0.5 {
                    SbsOutcome::PreferBaseline
                } else {
                    SbsOutcome::PreferOptimized
                }
            } else if rel > 0.0 {
                SbsOutcome::PreferBaseline
            } else {
                SbsOutcome::PreferOptimized
            }
        };
        if d_pair > tolerance * d_jnd {
            prefer_by_sharpness(&mut rng)
        } else if rng.next_f64() < self.p_noise_pref {
            // forced-choice noise on an indistinguishable pair
            prefer_by_sharpness(&mut rng)
        } else {
            SbsOutcome::Similar
        }
    }

    /// Run the full panel over a list of pairs, tallying all judgments.
    pub fn run(&self, pairs: &[(RgbImage, RgbImage)]) -> SbsTally {
        let mut tally = SbsTally::default();
        for (pair_idx, (base, opt)) in pairs.iter().enumerate() {
            for rater in 0..self.num_raters {
                tally.record(self.judge_one(base, opt, rater, pair_idx));
            }
        }
        tally
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noise_img(seed: u64, w: usize, h: usize) -> RgbImage {
        let mut rng = Rng::new(seed);
        let mut img = RgbImage::new(w, h);
        for b in img.data.iter_mut() {
            *b = rng.next_below(256) as u8;
        }
        img
    }

    fn judge() -> SbsJudge {
        SbsJudge::default()
    }

    #[test]
    fn identical_pairs_mostly_similar() {
        let img = noise_img(0, 32, 32);
        let tally = judge().run(&[(img.clone(), img.clone())]);
        assert_eq!(tally.total(), 6);
        // identical pairs: similar except forced-choice noise
        assert!(tally.similar >= 3, "{tally:?}");
    }

    #[test]
    fn very_different_pairs_never_similar() {
        let a = noise_img(1, 32, 32);
        let b = noise_img(2, 32, 32);
        let tally = judge().run(&[(a, b)]);
        assert_eq!(tally.similar, 0, "{tally:?}");
    }

    #[test]
    fn deterministic() {
        let a = noise_img(3, 16, 16);
        let b = noise_img(4, 16, 16);
        let j = judge();
        assert_eq!(j.run(&[(a.clone(), b.clone())]), j.run(&[(a, b)]));
    }

    #[test]
    fn sharpness_prefers_textured() {
        let flat = RgbImage::new(16, 16);
        let sharp = noise_img(5, 16, 16);
        assert!(sharpness(&sharp) > sharpness(&flat));
    }

    #[test]
    fn jnd_distance_positive_and_small() {
        let img = noise_img(6, 32, 32);
        let d = jnd_distance(&img, 0);
        assert!(d > 0.0 && d < 0.2, "jnd distance {d}");
    }

    #[test]
    fn sub_jnd_distortion_judged_similar_dominantly() {
        // distort by ±1 LSB (half the JND proxy) — panel should be
        // dominated by "similar" with a small noise-preference remainder
        let base = noise_img(7, 32, 32);
        let mut rng = Rng::new(8);
        let mut opt = base.clone();
        for b in opt.data.iter_mut() {
            let delta = rng.next_below(3) as i16 - 1;
            *b = (*b as i16 + delta).clamp(0, 255) as u8;
        }
        let j = SbsJudge { num_raters: 100, ..judge() };
        let tally = j.run(&[(base, opt)]);
        assert!(
            tally.pct_similar() > 50.0,
            "similar {}% too low",
            tally.pct_similar()
        );
        assert!(tally.prefer_baseline + tally.prefer_optimized > 0, "no rater noise at all");
    }

    #[test]
    fn super_jnd_distortion_flips_to_preference() {
        let base = noise_img(9, 32, 32);
        let mut rng = Rng::new(10);
        let mut opt = base.clone();
        for b in opt.data.iter_mut() {
            let v = *b as f64 + rng.next_normal() * 60.0;
            *b = v.clamp(0.0, 255.0) as u8;
        }
        let j = SbsJudge { num_raters: 50, ..judge() };
        let tally = j.run(&[(base, opt)]);
        assert!(tally.pct_similar() < 20.0, "similar {}%", tally.pct_similar());
    }

    #[test]
    fn tally_percentages_sum() {
        let mut t = SbsTally::default();
        for _ in 0..3 {
            t.record(SbsOutcome::Similar);
        }
        t.record(SbsOutcome::PreferBaseline);
        t.record(SbsOutcome::PreferOptimized);
        assert_eq!(t.total(), 5);
        let sum = t.pct_similar() + t.pct_baseline() + t.pct_optimized();
        assert!((sum - 100.0).abs() < 1e-9);
    }
}
