//! Prompt tokenizer — the CLIP-tokenizer substitute.
//!
//! The paper conditions SD on CLIP-tokenized prompts. Offline we cannot
//! ship CLIP's BPE vocabulary, so we use a deterministic *hash-bucket*
//! word tokenizer: lowercase, split on non-alphanumerics, FNV-1a hash
//! into the model's vocab range (reserving special ids). What matters for
//! the reproduction is that (a) the mapping is deterministic, (b) distinct
//! prompts map to distinct-enough id sequences to produce distinct
//! conditioning tensors, and (c) the *empty prompt* has a canonical
//! encoding (the unconditional branch of CFG). See DESIGN.md section 3.

/// Special token ids (reserved at the bottom of the vocab).
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
const NUM_SPECIAL: i64 = 3;

/// Deterministic hash-bucket tokenizer targeting a fixed vocab/seq-len.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab_size: usize,
    seq_len: usize,
}

impl Tokenizer {
    pub fn new(vocab_size: usize, seq_len: usize) -> Self {
        assert!(vocab_size as i64 > NUM_SPECIAL, "vocab too small");
        assert!(seq_len >= 2, "seq_len must fit BOS+EOS");
        Tokenizer { vocab_size, seq_len }
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab_size
    }

    fn fnv1a(word: &str) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for b in word.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    fn word_id(&self, word: &str) -> i32 {
        let range = self.vocab_size as u64 - NUM_SPECIAL as u64;
        (NUM_SPECIAL as u64 + Self::fnv1a(word) % range) as i32
    }

    /// Split into lowercase alphanumeric words.
    pub fn words(text: &str) -> Vec<String> {
        text.to_lowercase()
            .split(|c: char| !c.is_alphanumeric())
            .filter(|w| !w.is_empty())
            .map(String::from)
            .collect()
    }

    /// Encode to exactly `seq_len` ids: BOS, words..., EOS, PAD...
    /// Truncates long prompts (keeping EOS), pads short ones.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids = Vec::with_capacity(self.seq_len);
        ids.push(BOS);
        for w in Self::words(text) {
            if ids.len() >= self.seq_len - 1 {
                break;
            }
            ids.push(self.word_id(&w));
        }
        ids.push(EOS);
        while ids.len() < self.seq_len {
            ids.push(PAD);
        }
        ids
    }

    /// Canonical encoding of the *unconditional* (empty) prompt — the
    /// `eps(x_t | 0)` branch of Eq. 1.
    pub fn encode_uncond(&self) -> Vec<i32> {
        self.encode("")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::new(1024, 8)
    }

    #[test]
    fn encode_shape_and_specials() {
        let ids = tok().encode("A person holding a cat");
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], BOS);
        assert!(ids.contains(&EOS));
        for &id in &ids {
            assert!((0..1024).contains(&id));
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(tok().encode("hello world"), tok().encode("hello world"));
    }

    #[test]
    fn case_and_punctuation_normalized() {
        assert_eq!(tok().encode("Hello, WORLD!"), tok().encode("hello world"));
    }

    #[test]
    fn distinct_prompts_distinct_ids() {
        let a = tok().encode("a red ball");
        let b = tok().encode("a blue pyramid");
        assert_ne!(a, b);
    }

    #[test]
    fn uncond_is_bos_eos_pad() {
        let ids = tok().encode_uncond();
        assert_eq!(ids[0], BOS);
        assert_eq!(ids[1], EOS);
        assert!(ids[2..].iter().all(|&i| i == PAD));
    }

    #[test]
    fn truncation_keeps_eos() {
        let long = "one two three four five six seven eight nine ten";
        let ids = tok().encode(long);
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[7], EOS);
        assert!(!ids.contains(&PAD));
    }

    #[test]
    fn word_ids_avoid_specials() {
        let t = tok();
        for w in ["a", "cat", "dragon", "x1", "zzz"] {
            assert!(t.word_id(w) >= NUM_SPECIAL as i32);
        }
    }

    #[test]
    fn words_splitter() {
        assert_eq!(
            Tokenizer::words("3d-rendering of 5 tennis balls!"),
            vec!["3d", "rendering", "of", "5", "tennis", "balls"]
        );
        assert!(Tokenizer::words("  ., !").is_empty());
    }
}
