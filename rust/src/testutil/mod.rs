//! Test utilities, including the in-repo property-testing mini-framework
//! (`proptest` is not in the offline registry snapshot — DESIGN.md §5).

pub mod prop;

pub use prop::{forall, Gen};
