//! A small deterministic property-testing harness.
//!
//! Offline substitute for `proptest`: seeded generators, N random cases
//! per property, and first-failure reporting with the generator seed so a
//! failure reproduces exactly. No shrinking — cases are kept small
//! instead (the usual trade-off for a minimal harness).
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the xla_extension rpath
//! use selective_guidance::testutil::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Rng;

/// Per-case random value source.
pub struct Gen {
    rng: Rng,
    /// Seed identifying this case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn new(case_seed: u64) -> Self {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        self.rng.next_normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.normal_vec(n)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize_in(0, items.len() - 1)]
    }

    /// ASCII-ish word string of length in [1, max_len].
    pub fn word(&mut self, max_len: usize) -> String {
        let len = self.usize_in(1, max_len.max(1));
        (0..len)
            .map(|_| (b'a' + self.rng.next_below(26) as u8) as char)
            .collect()
    }
}

/// Run `cases` random cases of `property`. Panics (with the reproducing
/// case seed) on the first failing case.
///
/// The master seed is fixed so CI is deterministic; set the
/// `PROP_MASTER_SEED` environment variable to explore other universes.
pub fn forall(name: &str, cases: u64, mut property: impl FnMut(&mut Gen)) {
    let master: u64 = std::env::var("PROP_MASTER_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5E1EC71FE_u64);
    for case in 0..cases {
        let case_seed = master ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed on case {case} (case_seed={case_seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        forall("count", 50, |_| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            forall("always-fails", 10, |g| {
                let v = g.usize_in(0, 100);
                assert!(v > 1000, "v={v}");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("case_seed="), "{msg}");
        assert!(msg.contains("always-fails"), "{msg}");
    }

    #[test]
    fn generators_in_range() {
        forall("ranges", 200, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let i = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f64_in(0.25, 0.75);
            assert!((0.25..0.75).contains(&f));
            let w = g.word(6);
            assert!(!w.is_empty() && w.len() <= 6);
        });
    }

    #[test]
    fn deterministic_given_same_master() {
        // same env -> same sequence of case seeds -> same values
        let mut first: Vec<u64> = Vec::new();
        forall("record", 5, |g| first.push(g.u64()));
        let mut second: Vec<u64> = Vec::new();
        forall("record", 5, |g| second.push(g.u64()));
        assert_eq!(first, second);
    }
}
