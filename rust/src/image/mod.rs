//! Image output: tensor → RGB conversion, PNG and PPM encoders.
//!
//! The offline registry snapshot has no `image`/`png` crate, so the PNG
//! encoder is implemented here directly on top of `flate2` (zlib) and
//! `crc32fast` — both available. Output is standard 8-bit RGB PNG.

mod png;
mod ppm;

pub use png::encode_png;
pub use ppm::encode_ppm;

use crate::error::{Error, Result};

/// An 8-bit RGB image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RgbImage {
    pub width: usize,
    pub height: usize,
    /// Row-major RGB triples, `3 * width * height` bytes.
    pub data: Vec<u8>,
}

impl RgbImage {
    pub fn new(width: usize, height: usize) -> Self {
        RgbImage { width, height, data: vec![0; 3 * width * height] }
    }

    /// Build from a CHW float tensor in [-1, 1] (the VAE decoder output).
    ///
    /// `chw` must have shape `[3, height, width]` flattened.
    pub fn from_chw_f32(chw: &[f32], height: usize, width: usize) -> Result<Self> {
        let expect = 3 * height * width;
        if chw.len() != expect {
            return Err(Error::Request(format!(
                "image tensor has {} elements, expected {}",
                chw.len(),
                expect
            )));
        }
        let mut img = RgbImage::new(width, height);
        let plane = height * width;
        for y in 0..height {
            for x in 0..width {
                let p = y * width + x;
                for c in 0..3 {
                    let v = chw[c * plane + p];
                    let byte = (((v.clamp(-1.0, 1.0) + 1.0) * 0.5) * 255.0).round() as u8;
                    img.data[3 * p + c] = byte;
                }
            }
        }
        Ok(img)
    }

    pub fn pixel(&self, x: usize, y: usize) -> [u8; 3] {
        let p = 3 * (y * self.width + x);
        [self.data[p], self.data[p + 1], self.data[p + 2]]
    }

    pub fn set_pixel(&mut self, x: usize, y: usize, rgb: [u8; 3]) {
        let p = 3 * (y * self.width + x);
        self.data[p..p + 3].copy_from_slice(&rgb);
    }

    /// Per-pixel luma (ITU-R BT.601), used by the quality metrics.
    pub fn luma(&self) -> Vec<f32> {
        self.data
            .chunks_exact(3)
            .map(|p| 0.299 * p[0] as f32 + 0.587 * p[1] as f32 + 0.114 * p[2] as f32)
            .collect()
    }

    /// Write as PNG.
    pub fn save_png(&self, path: &std::path::Path) -> Result<()> {
        let bytes = encode_png(self)?;
        std::fs::write(path, bytes)
            .map_err(|e| Error::io(format!("writing {}", path.display()), e))
    }

    /// Write as binary PPM (P6).
    pub fn save_ppm(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, encode_ppm(self))
            .map_err(|e| Error::io(format!("writing {}", path.display()), e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_chw_maps_range() {
        // 1x1 image: channel values -1, 0, 1 -> 0, 128, 255
        let img = RgbImage::from_chw_f32(&[-1.0, 0.0, 1.0], 1, 1).unwrap();
        assert_eq!(img.pixel(0, 0), [0, 128, 255]);
    }

    #[test]
    fn from_chw_clamps() {
        let img = RgbImage::from_chw_f32(&[-5.0, 9.0, 0.0], 1, 1).unwrap();
        assert_eq!(img.pixel(0, 0), [0, 255, 128]);
    }

    #[test]
    fn from_chw_rejects_bad_len() {
        assert!(RgbImage::from_chw_f32(&[0.0; 5], 1, 1).is_err());
    }

    #[test]
    fn chw_layout_correct() {
        // 2x1 image, distinct per-channel planes
        // R plane [10, 20], G plane [30, 40], B plane [50, 60] in [-1,1]-ish
        let to_f = |b: u8| (b as f32 / 255.0) * 2.0 - 1.0;
        let chw = vec![to_f(10), to_f(20), to_f(30), to_f(40), to_f(50), to_f(60)];
        let img = RgbImage::from_chw_f32(&chw, 1, 2).unwrap();
        assert_eq!(img.pixel(0, 0), [10, 30, 50]);
        assert_eq!(img.pixel(1, 0), [20, 40, 60]);
    }

    #[test]
    fn luma_white_is_255() {
        let mut img = RgbImage::new(1, 1);
        img.set_pixel(0, 0, [255, 255, 255]);
        let l = img.luma();
        assert!((l[0] - 255.0).abs() < 0.5);
    }
}
