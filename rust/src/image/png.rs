//! Minimal standards-compliant PNG encoder (8-bit RGB, no interlace).
//!
//! Built directly on `flate2` (zlib stream) + `crc32fast` (chunk CRCs).
//! Uses per-row filter heuristics (None vs Sub vs Up, minimum-sum-of-
//! absolute-differences) — small files without a full filter search.

use std::io::Write;

use crate::error::{Error, Result};

use super::RgbImage;

const PNG_SIGNATURE: [u8; 8] = [0x89, b'P', b'N', b'G', b'\r', b'\n', 0x1A, b'\n'];

fn chunk(out: &mut Vec<u8>, kind: &[u8; 4], data: &[u8]) {
    out.extend_from_slice(&(data.len() as u32).to_be_bytes());
    out.extend_from_slice(kind);
    out.extend_from_slice(data);
    let mut h = crc32fast::Hasher::new();
    h.update(kind);
    h.update(data);
    out.extend_from_slice(&h.finalize().to_be_bytes());
}

/// Apply the `Sub` filter (delta vs previous pixel) into `dst`.
fn filter_sub(row: &[u8], bpp: usize, dst: &mut Vec<u8>) {
    dst.clear();
    dst.extend_from_slice(row);
    for i in (bpp..dst.len()).rev() {
        dst[i] = dst[i].wrapping_sub(row[i - bpp]);
    }
}

/// Apply the `Up` filter (delta vs previous row) into `dst`.
fn filter_up(row: &[u8], prev: &[u8], dst: &mut Vec<u8>) {
    dst.clear();
    dst.extend(row.iter().zip(prev).map(|(&a, &b)| a.wrapping_sub(b)));
}

fn sad(filtered: &[u8]) -> u64 {
    // sum of absolute differences, treating bytes as signed — the
    // standard PNG filter heuristic
    filtered.iter().map(|&b| (b as i8).unsigned_abs() as u64).sum()
}

/// Encode an [`RgbImage`] to PNG bytes.
pub fn encode_png(img: &RgbImage) -> Result<Vec<u8>> {
    if img.width == 0 || img.height == 0 {
        return Err(Error::Request("cannot encode empty image".into()));
    }
    if img.data.len() != 3 * img.width * img.height {
        return Err(Error::Request("image buffer size mismatch".into()));
    }

    let bpp = 3usize;
    let stride = bpp * img.width;

    // build the filtered scanline stream
    let mut raw = Vec::with_capacity((stride + 1) * img.height);
    let zero_row = vec![0u8; stride];
    let mut buf_sub = Vec::with_capacity(stride);
    let mut buf_up = Vec::with_capacity(stride);
    for y in 0..img.height {
        let row = &img.data[y * stride..(y + 1) * stride];
        let prev = if y == 0 { &zero_row[..] } else { &img.data[(y - 1) * stride..y * stride] };
        filter_sub(row, bpp, &mut buf_sub);
        filter_up(row, prev, &mut buf_up);
        let s_none = sad(row);
        let s_sub = sad(&buf_sub);
        let s_up = sad(&buf_up);
        if s_sub <= s_none && s_sub <= s_up {
            raw.push(1u8);
            raw.extend_from_slice(&buf_sub);
        } else if s_up <= s_none {
            raw.push(2u8);
            raw.extend_from_slice(&buf_up);
        } else {
            raw.push(0u8);
            raw.extend_from_slice(row);
        }
    }

    // zlib-compress the stream
    let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::new(6));
    enc.write_all(&raw)
        .and_then(|_| enc.finish())
        .map_err(|e| Error::io("png zlib compression", e))
        .map(|compressed| {
            let mut out = Vec::with_capacity(compressed.len() + 128);
            out.extend_from_slice(&PNG_SIGNATURE);
            // IHDR
            let mut ihdr = Vec::with_capacity(13);
            ihdr.extend_from_slice(&(img.width as u32).to_be_bytes());
            ihdr.extend_from_slice(&(img.height as u32).to_be_bytes());
            ihdr.push(8); // bit depth
            ihdr.push(2); // color type: truecolor RGB
            ihdr.push(0); // compression
            ihdr.push(0); // filter method
            ihdr.push(0); // no interlace
            chunk(&mut out, b"IHDR", &ihdr);
            chunk(&mut out, b"IDAT", &compressed);
            chunk(&mut out, b"IEND", &[]);
            out
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;

    fn decode_idat(png: &[u8]) -> Vec<u8> {
        // walk chunks, collect IDAT, inflate
        assert_eq!(&png[..8], &PNG_SIGNATURE);
        let mut pos = 8;
        let mut idat = Vec::new();
        while pos < png.len() {
            let len = u32::from_be_bytes(png[pos..pos + 4].try_into().unwrap()) as usize;
            let kind = &png[pos + 4..pos + 8];
            let data = &png[pos + 8..pos + 8 + len];
            // CRC check
            let mut h = crc32fast::Hasher::new();
            h.update(kind);
            h.update(data);
            let crc = u32::from_be_bytes(png[pos + 8 + len..pos + 12 + len].try_into().unwrap());
            assert_eq!(h.finalize(), crc, "bad CRC for {:?}", std::str::from_utf8(kind));
            if kind == b"IDAT" {
                idat.extend_from_slice(data);
            }
            pos += 12 + len;
        }
        let mut out = Vec::new();
        flate2::read::ZlibDecoder::new(&idat[..]).read_to_end(&mut out).unwrap();
        out
    }

    fn unfilter(raw: &[u8], width: usize, height: usize) -> Vec<u8> {
        let stride = 3 * width;
        let mut img = vec![0u8; stride * height];
        for y in 0..height {
            let ftype = raw[y * (stride + 1)];
            let src = &raw[y * (stride + 1) + 1..(y + 1) * (stride + 1)];
            for i in 0..stride {
                let left = if i >= 3 { img[y * stride + i - 3] } else { 0 };
                let up = if y > 0 { img[(y - 1) * stride + i] } else { 0 };
                img[y * stride + i] = match ftype {
                    0 => src[i],
                    1 => src[i].wrapping_add(left),
                    2 => src[i].wrapping_add(up),
                    _ => panic!("unexpected filter {ftype}"),
                };
            }
        }
        img
    }

    #[test]
    fn round_trip_gradient() {
        let mut img = RgbImage::new(16, 9);
        for y in 0..9 {
            for x in 0..16 {
                img.set_pixel(x, y, [(x * 16) as u8, (y * 28) as u8, ((x + y) * 9) as u8]);
            }
        }
        let png = encode_png(&img).unwrap();
        let raw = decode_idat(&png);
        assert_eq!(raw.len(), (3 * 16 + 1) * 9);
        let decoded = unfilter(&raw, 16, 9);
        assert_eq!(decoded, img.data);
    }

    #[test]
    fn round_trip_noise() {
        let mut rng = crate::rng::Rng::new(0);
        let mut img = RgbImage::new(33, 17); // odd sizes
        for b in img.data.iter_mut() {
            *b = rng.next_below(256) as u8;
        }
        let png = encode_png(&img).unwrap();
        assert_eq!(unfilter(&decode_idat(&png), 33, 17), img.data);
    }

    #[test]
    fn header_fields() {
        let img = RgbImage::new(640, 480);
        let png = encode_png(&img).unwrap();
        assert_eq!(&png[..8], &PNG_SIGNATURE);
        let w = u32::from_be_bytes(png[16..20].try_into().unwrap());
        let h = u32::from_be_bytes(png[20..24].try_into().unwrap());
        assert_eq!((w, h), (640, 480));
        assert_eq!(png[24], 8); // bit depth
        assert_eq!(png[25], 2); // RGB
        assert_eq!(&png[png.len() - 8..png.len() - 4], b"IEND");
    }

    #[test]
    fn flat_image_compresses_well() {
        let img = RgbImage::new(128, 128); // all black
        let png = encode_png(&img).unwrap();
        assert!(png.len() < 1200, "flat image should compress, got {}", png.len());
    }

    #[test]
    fn rejects_empty() {
        assert!(encode_png(&RgbImage::new(0, 4)).is_err());
    }
}
