//! Binary PPM (P6) writer — zero-dependency fallback output format,
//! convenient for quick inspection with any netpbm-aware viewer.

use super::RgbImage;

/// Encode an [`RgbImage`] as binary PPM bytes.
pub fn encode_ppm(img: &RgbImage) -> Vec<u8> {
    let header = format!("P6\n{} {}\n255\n", img.width, img.height);
    let mut out = Vec::with_capacity(header.len() + img.data.len());
    out.extend_from_slice(header.as_bytes());
    out.extend_from_slice(&img.data);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_and_payload() {
        let mut img = RgbImage::new(2, 1);
        img.set_pixel(0, 0, [1, 2, 3]);
        img.set_pixel(1, 0, [4, 5, 6]);
        let ppm = encode_ppm(&img);
        assert!(ppm.starts_with(b"P6\n2 1\n255\n"));
        assert_eq!(&ppm[ppm.len() - 6..], &[1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn size_formula() {
        let img = RgbImage::new(10, 7);
        let ppm = encode_ppm(&img);
        assert_eq!(ppm.len(), "P6\n10 7\n255\n".len() + 3 * 10 * 7);
    }
}
