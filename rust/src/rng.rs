//! Deterministic RNG for latent initialization and workload generation.
//!
//! The paper holds the random seed constant across its comparisons
//! ("the random seed was held constant", §3) — reproducible noise is a
//! correctness requirement here, not a convenience. We use SplitMix64 for
//! stream derivation and xoshiro256++ for bulk generation, with a
//! Box–Muller transform for standard normals (latent init).
//!
//! No external crates: the registry snapshot available offline has no
//! `rand`; `rand_core` alone provides no generators worth pulling in.

/// SplitMix64 — used to seed / derive independent streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — bulk generator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream for (seed, stream-id) — used to give
    /// each request its own reproducible noise stream.
    pub fn for_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Rng { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0, 1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn next_normal(&mut self) -> f64 {
        // (no cache to keep the generator state a pure function of draws)
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a buffer with standard-normal f32s (latent initialization).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_normal() as f32;
        }
    }

    /// A fresh standard-normal vector of length `n`.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v);
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            slice.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Rng::for_stream(7, 0);
        let mut b = Rng::for_stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
        // same (seed, stream) reproduces
        let mut c = Rng::for_stream(7, 1);
        let mut d = Rng::for_stream(7, 1);
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.next_normal();
            sum += v;
            sum2 += v * v;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }
}
