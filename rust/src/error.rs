//! Crate-wide error type.

use std::fmt;

/// Unified error for the serving stack.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// PJRT / XLA failures (compile, execute, literal conversion).
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact loading / manifest problems.
    #[error("artifact: {0}")]
    Artifact(String),

    /// JSON parse errors (manifests, wire protocol).
    #[error("json: {0}")]
    Json(String),

    /// Configuration errors (invalid values, unknown keys).
    #[error("config: {0}")]
    Config(String),

    /// Request validation failures (bad steps, batch, prompt).
    #[error("request: {0}")]
    Request(String),

    /// Coordinator lifecycle problems (shutdown, disconnected workers).
    #[error("coordinator: {0}")]
    Coordinator(String),

    /// Wire-protocol violations on the TCP front-end.
    #[error("protocol: {0}")]
    Protocol(String),

    /// I/O, with context.
    #[error("io: {context}: {source}")]
    Io {
        context: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper for formatting shape vectors in messages.
pub fn fmt_shape(shape: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, d) in shape.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        fmt::Write::write_fmt(&mut s, format_args!("{d}")).unwrap();
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_context() {
        let e = Error::io("reading manifest", std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let s = e.to_string();
        assert!(s.contains("reading manifest"), "{s}");
    }

    #[test]
    fn fmt_shape_matches_convention() {
        assert_eq!(fmt_shape(&[1, 4, 8, 8]), "[1,4,8,8]");
        assert_eq!(fmt_shape(&[]), "[]");
    }
}
