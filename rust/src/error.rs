//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline
//! registry snapshot) — the formats below are load-bearing: tests and
//! the wire protocol match on them.

use std::fmt;

use crate::xla;

/// Unified error for the serving stack.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA failures (compile, execute, literal conversion).
    Xla(String),

    /// Artifact loading / manifest problems.
    Artifact(String),

    /// JSON parse errors (manifests, wire protocol).
    Json(String),

    /// Configuration errors (invalid values, unknown keys).
    Config(String),

    /// Request validation failures (bad steps, batch, prompt).
    Request(String),

    /// Coordinator lifecycle problems (shutdown, disconnected workers).
    Coordinator(String),

    /// Wire-protocol violations on the TCP front-end.
    Protocol(String),

    /// A per-sample engine execution failure (e.g. a reuse step reaching
    /// a cold uncond cache). Fails only the offending sample — the
    /// serving layers must never treat it as a cohort-wide poison, and
    /// the cluster relay must not requeue it (it would fail identically
    /// on every replica).
    Engine(String),

    /// QoS admission rejection — the explicit load-shedding path. `code`
    /// follows HTTP semantics (429 queue full, 503 infeasible) so the
    /// server front-end can surface it without string matching.
    Rejected { code: u16, reason: String },

    /// A request's deadline expired before (or while) it was served.
    DeadlineExceeded(String),

    /// The client cancelled the request mid-flight. Not a failure: the
    /// sample is dropped without `finish()`, its slots return to the
    /// continuous-batch headroom, and the cluster relay must never
    /// requeue it (the client already walked away).
    Cancelled(String),

    /// I/O, with context.
    Io {
        context: String,
        source: std::io::Error,
    },
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Request(m) => write!(f, "request: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Protocol(m) => write!(f, "protocol: {m}"),
            Error::Engine(m) => write!(f, "engine: {m}"),
            Error::Rejected { code, reason } => write!(f, "rejected ({code}): {reason}"),
            Error::DeadlineExceeded(m) => write!(f, "deadline exceeded: {m}"),
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
            Error::Io { context, source } => write!(f, "io: {context}: {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl Error {
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }

    /// The HTTP-style status code of a QoS outcome error, if any.
    pub fn qos_code(&self) -> Option<u16> {
        match self {
            Error::Rejected { code, .. } => Some(*code),
            Error::DeadlineExceeded(_) => Some(504),
            // 499: client closed the request (nginx convention).
            Error::Cancelled(_) => Some(499),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Helper for formatting shape vectors in messages.
pub fn fmt_shape(shape: &[usize]) -> String {
    let mut s = String::from("[");
    for (i, d) in shape.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        fmt::Write::write_fmt(&mut s, format_args!("{d}")).unwrap();
    }
    s.push(']');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_context() {
        let e = Error::io(
            "reading manifest",
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        let s = e.to_string();
        assert!(s.contains("reading manifest"), "{s}");
    }

    #[test]
    fn fmt_shape_matches_convention() {
        assert_eq!(fmt_shape(&[1, 4, 8, 8]), "[1,4,8,8]");
        assert_eq!(fmt_shape(&[]), "[]");
    }

    #[test]
    fn io_error_exposes_source() {
        let e = Error::io("ctx", std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let src = std::error::Error::source(&e).expect("io carries a source");
        assert_eq!(src.to_string(), "inner");
        assert!(std::error::Error::source(&Error::Config("x".into())).is_none());
    }

    #[test]
    fn engine_error_display() {
        let e = Error::Engine("reuse step 3 with a cold uncond cache".into());
        assert_eq!(e.to_string(), "engine: reuse step 3 with a cold uncond cache");
        // a per-sample engine failure carries no QoS status code
        assert_eq!(e.qos_code(), None);
    }

    #[test]
    fn qos_codes() {
        let r = Error::Rejected { code: 429, reason: "queue full".into() };
        assert_eq!(r.qos_code(), Some(429));
        assert!(r.to_string().contains("429"), "{r}");
        assert_eq!(Error::DeadlineExceeded("late".into()).qos_code(), Some(504));
        assert_eq!(Error::Config("x".into()).qos_code(), None);
    }

    #[test]
    fn cancelled_is_a_qos_outcome() {
        let c = Error::Cancelled("client closed stream".into());
        assert_eq!(c.to_string(), "cancelled: client closed stream");
        assert_eq!(c.qos_code(), Some(499));
    }
}
