"""L2 performance analysis: HLO cost profile of every artifact.

Feeds DESIGN.md §Perf / EXPERIMENTS.md §Perf: per-artifact FLOP count,
transcendental count, bytes accessed (XLA's HloCostAnalysis), the op-kind
histogram, and derived quantities the optimization pass tracks:

  * flops per UNet eval — the denominator of the efficiency ratio;
  * dual-step vs optimized-step FLOP ratio (paper: 2x, §3.3);
  * arithmetic intensity (flops/byte) — roofline position on CPU/TPU;
  * fusion health: ratio of fusion ops to total ops after optimization.

Usage:
    python -m compile.profile [--out ../artifacts] [--presets tiny,small]

Writes `artifacts/<preset>/profile.json` next to the manifest and prints a
summary table. Uses the same jax lowering path as aot.py, then runs XLA's
compiler to get the *optimized* module (what PJRT actually executes).
"""

import argparse
import collections
import json
import os
import re
import sys

import jax

from . import aot, configs


def _client():
    return jax.devices("cpu")[0].client


def analyze_hlo_text(hlo_text: str) -> dict:
    """Compile HLO text and run HloCostAnalysis on the optimized module."""
    from jax._src.lib import xla_client as xc

    comp = xc._xla.mlir.mlir_module_to_xla_computation  # noqa: SLF001
    del comp  # text path below

    backend = _client()
    # parse the HLO text back into a computation via the round-trip the
    # rust side uses is not exposed in jax; instead re-lower from the
    # original program. Here we only need op statistics, so fall back to
    # text parsing for the histogram and use jax's cost analysis on the
    # compiled executable for flops.
    ops = collections.Counter()
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        # the op name is the first [a-z-]+ token directly followed by '('
        # after the result type (types never end with a lowercase token
        # right before '(' — tuple-type parens are preceded by space/=)
        m = re.search(r"(?<![\w\-])([a-z][a-z\-]*[a-z])\(", rhs)
        if m:
            ops[m.group(1)] += 1
    del backend
    return dict(ops)


def analyze_artifact(fn, arg_specs) -> dict:
    """Lower + compile a jax function; return cost-analysis numbers."""
    lowered = jax.jit(fn).lower(*arg_specs)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    cost = dict(cost or {})
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    transcendentals = float(cost.get("transcendentals", 0.0))
    return {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "transcendentals": transcendentals,
        "arithmetic_intensity": flops / bytes_accessed if bytes_accessed else 0.0,
    }


def profile_preset(cfg: configs.ModelConfig, out_root: str) -> dict:
    import jax.numpy as jnp

    from . import model, params

    C, H, W = cfg.latent_shape
    S, D = cfg.seq_len, cfg.text_dim

    lat = jnp.zeros((1, C, H, W))
    t = jnp.zeros((1,))
    ctx = jnp.zeros((1, S, D))
    ids = jnp.zeros((1, S), jnp.int32)

    uflat = params.init_flat(
        lambda cur: model.unet(cur, cfg, lat, t, ctx), cfg.seed)
    tflat = params.init_flat(
        lambda cur: model.text_encoder(cur, cfg, ids), cfg.seed + 1)
    vflat = params.init_flat(
        lambda cur: model.vae_decoder(cur, cfg, lat), cfg.seed + 2)

    def unet_fn(p, lt, tt, cc):
        return (model.unet(params.ParamCursor(flat=p), cfg, lt, tt, cc),)

    def te_fn(p, ii):
        return (model.text_encoder(params.ParamCursor(flat=p), cfg, ii),)

    def vae_fn(p, lt):
        return (model.vae_decoder(params.ParamCursor(flat=p), cfg, lt),)

    spec = aot.spec
    report = {"preset": cfg.name, "artifacts": {}}

    for b in (1, 2):
        entry = analyze_artifact(
            unet_fn,
            (spec((uflat.shape[0],)), spec((b, C, H, W)), spec((b,)),
             spec((b, S, D))))
        report["artifacts"][f"unet_b{b}"] = entry
    report["artifacts"]["text_encoder"] = analyze_artifact(
        te_fn, (spec((tflat.shape[0],)), aot.spec((1, S), jnp.int32)))
    report["artifacts"]["vae_decoder"] = analyze_artifact(
        vae_fn, (spec((vflat.shape[0],)), spec((1, C, H, W))))

    # derived quantities for the §Perf ledger
    u1 = report["artifacts"]["unet_b1"]["flops"]
    u2 = report["artifacts"]["unet_b2"]["flops"]
    report["derived"] = {
        "unet_eval_gflops": u1 / 1e9,
        # dual CFG step = 2x b1 (split) or 1x b2 (fused); optimized = 1x b1
        "dual_step_over_optimized_split": 2.0,
        "dual_step_over_optimized_fused": u2 / u1 if u1 else 0.0,
        "paper_expected_ratio": 2.0,
    }
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "profile.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {path}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default="tiny,small")
    args = ap.parse_args(argv)
    for name in args.presets.split(","):
        cfg = configs.preset(name.strip())
        r = profile_preset(cfg, args.out)
        print(f"\npreset {cfg.name}:")
        print(f"  {'artifact':<14} {'GFLOP':>9} {'MB moved':>9} {'AI (f/B)':>9}")
        for art, e in sorted(r["artifacts"].items()):
            print(
                f"  {art:<14} {e['flops'] / 1e9:>9.4f} "
                f"{e['bytes_accessed'] / 1e6:>9.2f} "
                f"{e['arithmetic_intensity']:>9.2f}")
        d = r["derived"]
        print(
            f"  fused dual/optimized FLOP ratio: "
            f"{d['dual_step_over_optimized_fused']:.2f} (paper model: 2.0)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
