"""L2: the guided-diffusion model family in JAX.

Three compute graphs, each AOT-lowered to an HLO artifact by ``aot.py``:

  * ``unet``          — latent-space denoising UNet (ResBlocks + transformer
                        blocks with self- and cross-attention, sinusoidal
                        timestep embedding, down/up-sampling). The paper's
                        SD v1.x UNet at reduced scale (DESIGN.md section 3).
  * ``text_encoder``  — CLIP-substitute transformer encoder mapping token
                        ids to the cross-attention context.
  * ``vae_decoder``   — conv decoder mapping latents to RGB images.

All hot-spots route through the L1 Pallas kernels
(``kernels.flash_attention``, ``kernels.groupnorm_silu``); the Eq.-1 CFG
combine ships as its own artifact so the rust engine can fuse the two UNet
outputs on-device. ``use_pallas=False`` swaps in the pure-jnp oracles for
fast shape tests.

Every graph takes the flat parameter vector as its first argument — see
``params.ParamCursor`` for the layout contract.
"""

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .configs import ModelConfig
from .kernels import flash_attention, groupnorm_silu
from .kernels import ref as kref

# dimension numbers for NCHW conv with OIHW kernels
_DN = ("NCHW", "OIHW", "NCHW")


# ---------------------------------------------------------------------------
# primitive layers
# ---------------------------------------------------------------------------

def conv2d(cur, x, cin: int, cout: int, k: int = 3, stride: int = 1,
           name: str = "conv"):
    """3x3/1x1 convolution with bias, SAME padding."""
    w = cur.take((cout, cin, k, k), init="normal", fan_in=cin * k * k,
                 name=f"{name}.w")
    b = cur.take((cout,), init="zeros", name=f"{name}.b")
    pad = k // 2
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)), dimension_numbers=_DN)
    return y + b.reshape(1, cout, 1, 1)


def dense(cur, x, din: int, dout: int, name: str = "dense"):
    w = cur.take((din, dout), init="normal", fan_in=din, name=f"{name}.w")
    b = cur.take((dout,), init="zeros", name=f"{name}.b")
    return x @ w + b


def layernorm(cur, x, dim: int, name: str = "ln"):
    g = cur.take((dim,), init="ones", name=f"{name}.g")
    b = cur.take((dim,), init="zeros", name=f"{name}.b")
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) * lax.rsqrt(var + 1e-5) * g + b


def groupnorm_plain(cur, x, ch: int, groups: int, name: str = "gn"):
    """GroupNorm without activation (pre-attention norm)."""
    g = cur.take((ch,), init="ones", name=f"{name}.g")
    b = cur.take((ch,), init="zeros", name=f"{name}.b")
    bsz, c, h, w = x.shape
    xg = x.reshape(bsz, groups, c // groups, h, w)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    xn = ((xg - mean) * lax.rsqrt(var + 1e-5)).reshape(bsz, c, h, w)
    return xn * g.reshape(1, c, 1, 1) + b.reshape(1, c, 1, 1)


def gn_silu(cur, x, ch: int, groups: int, use_pallas: bool, name: str = "gns"):
    """Fused GroupNorm+SiLU via the L1 kernel (or its oracle)."""
    g = cur.take((ch,), init="ones", name=f"{name}.g")
    b = cur.take((ch,), init="zeros", name=f"{name}.b")
    if use_pallas:
        return groupnorm_silu(x, g, b, groups=groups)
    return kref.groupnorm_silu_ref(x, g, b, groups)


def attention(q, k, v, heads: int, use_pallas: bool):
    """Multi-head attention dispatch. q: [B,Sq,C]; k/v: [B,Skv,C]."""
    bsz, sq, c = q.shape
    skv = k.shape[1]
    d = c // heads

    def split(t, s):
        return (t.reshape(bsz, s, heads, d).transpose(0, 2, 1, 3)
                .reshape(bsz * heads, s, d))

    qh, kh, vh = split(q, sq), split(k, skv), split(v, skv)
    if use_pallas:
        oh = flash_attention(qh, kh, vh)
    else:
        oh = kref.attention_ref(qh, kh, vh)
    return (oh.reshape(bsz, heads, sq, d).transpose(0, 2, 1, 3)
            .reshape(bsz, sq, c))


def timestep_embedding(t, dim: int):
    """Sinusoidal embedding of (continuous) timesteps. t: [B] -> [B, dim]."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = t[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


# ---------------------------------------------------------------------------
# UNet blocks
# ---------------------------------------------------------------------------

def resblock(cur, x, temb, cin: int, cout: int, groups: int,
             use_pallas: bool, name: str):
    """GN+SiLU -> conv -> +temb -> GN+SiLU -> conv, with skip."""
    h = gn_silu(cur, x, cin, groups, use_pallas, name=f"{name}.gns1")
    h = conv2d(cur, h, cin, cout, 3, name=f"{name}.conv1")
    te = dense(cur, kref.silu_ref(temb), temb.shape[-1], cout,
               name=f"{name}.temb")
    h = h + te[:, :, None, None]
    h = gn_silu(cur, h, cout, groups, use_pallas, name=f"{name}.gns2")
    h = conv2d(cur, h, cout, cout, 3, name=f"{name}.conv2")
    if cin != cout:
        x = conv2d(cur, x, cin, cout, 1, name=f"{name}.skip")
    return x + h


def transformer_block(cur, x, ctx, ch: int, heads: int, groups: int,
                      text_dim: int, use_pallas: bool, name: str):
    """Self-attn + cross-attn(ctx) + feed-forward over latent tokens.

    x: [B, ch, H, W]; ctx: [B, S, text_dim].
    """
    bsz, c, h, w = x.shape
    hn = groupnorm_plain(cur, x, ch, groups, name=f"{name}.gn")
    tok = hn.reshape(bsz, c, h * w).transpose(0, 2, 1)  # [B, HW, C]

    # self-attention
    t1 = layernorm(cur, tok, ch, name=f"{name}.ln1")
    q = dense(cur, t1, ch, ch, name=f"{name}.sa.q")
    k = dense(cur, t1, ch, ch, name=f"{name}.sa.k")
    v = dense(cur, t1, ch, ch, name=f"{name}.sa.v")
    a = attention(q, k, v, heads, use_pallas)
    tok = tok + dense(cur, a, ch, ch, name=f"{name}.sa.o")

    # cross-attention over the text context
    t2 = layernorm(cur, tok, ch, name=f"{name}.ln2")
    q = dense(cur, t2, ch, ch, name=f"{name}.ca.q")
    k = dense(cur, ctx, text_dim, ch, name=f"{name}.ca.k")
    v = dense(cur, ctx, text_dim, ch, name=f"{name}.ca.v")
    a = attention(q, k, v, heads, use_pallas)
    tok = tok + dense(cur, a, ch, ch, name=f"{name}.ca.o")

    # feed-forward
    t3 = layernorm(cur, tok, ch, name=f"{name}.ln3")
    ff = dense(cur, t3, ch, 4 * ch, name=f"{name}.ff1")
    ff = dense(cur, kref.silu_ref(ff), 4 * ch, ch, name=f"{name}.ff2")
    tok = tok + ff

    return x + tok.transpose(0, 2, 1).reshape(bsz, c, h, w)


def downsample(cur, x, ch: int, name: str):
    return conv2d(cur, x, ch, ch, 3, stride=2, name=name)


def upsample(cur, x, ch: int, name: str):
    bsz, c, h, w = x.shape
    up = jax.image.resize(x, (bsz, c, 2 * h, 2 * w), method="nearest")
    return conv2d(cur, up, ch, ch, 3, name=name)


# ---------------------------------------------------------------------------
# the three compute graphs
# ---------------------------------------------------------------------------

def unet(cur, cfg: ModelConfig, latent, t, ctx, use_pallas: bool = True):
    """Denoising UNet: predict eps from (x_t, t, context).

    latent: [B, C, H, W]; t: [B] (continuous timestep index);
    ctx: [B, S, text_dim]  ->  eps: [B, C, H, W]
    """
    chs = cfg.channels
    g = cfg.groupnorm_groups
    ted = cfg.time_embed_dim

    temb = timestep_embedding(t, chs[0])
    temb = dense(cur, temb, chs[0], ted, name="temb1")
    temb = dense(cur, kref.silu_ref(temb), ted, ted, name="temb2")

    h = conv2d(cur, latent, cfg.latent_channels, chs[0], 3, name="in")
    skips = [(h, chs[0])]

    # down path
    for lvl, ch in enumerate(chs):
        cin = chs[max(lvl - 1, 0)] if lvl > 0 else chs[0]
        for i in range(cfg.blocks_per_level):
            h = resblock(cur, h, temb, cin if i == 0 else ch, ch, g,
                         use_pallas, name=f"down{lvl}.res{i}")
            if lvl in cfg.attn_levels:
                h = transformer_block(cur, h, ctx, ch, cfg.num_heads, g,
                                      cfg.text_dim, use_pallas,
                                      name=f"down{lvl}.attn{i}")
            skips.append((h, ch))
        if lvl < len(chs) - 1:
            h = downsample(cur, h, ch, name=f"down{lvl}.ds")
            skips.append((h, ch))

    # middle
    mid_ch = chs[-1]
    h = resblock(cur, h, temb, mid_ch, mid_ch, g, use_pallas, name="mid.res1")
    h = transformer_block(cur, h, ctx, mid_ch, cfg.num_heads, g,
                          cfg.text_dim, use_pallas, name="mid.attn")
    h = resblock(cur, h, temb, mid_ch, mid_ch, g, use_pallas, name="mid.res2")

    # up path (mirror, consuming skips)
    for lvl in reversed(range(len(chs))):
        ch = chs[lvl]
        n_blocks = cfg.blocks_per_level + (1 if lvl < len(chs) - 1 else 1)
        for i in range(n_blocks):
            skip, sk_ch = skips.pop()
            cin = h.shape[1] + sk_ch
            h = jnp.concatenate([h, skip], axis=1)
            h = resblock(cur, h, temb, cin, ch, g, use_pallas,
                         name=f"up{lvl}.res{i}")
            if lvl in cfg.attn_levels:
                h = transformer_block(cur, h, ctx, ch, cfg.num_heads, g,
                                      cfg.text_dim, use_pallas,
                                      name=f"up{lvl}.attn{i}")
        if lvl > 0:
            h = upsample(cur, h, ch, name=f"up{lvl}.us")

    h = gn_silu(cur, h, chs[0], g, use_pallas, name="out.gns")
    return conv2d(cur, h, chs[0], cfg.latent_channels, 3, name="out.conv")


def text_encoder(cur, cfg: ModelConfig, ids, use_pallas: bool = True):
    """CLIP-substitute encoder. ids: i32[B, S] -> ctx f32[B, S, text_dim]."""
    d = cfg.text_dim
    table = cur.take((cfg.vocab_size, d), init="embed", name="te.tok")
    pos = cur.take((cfg.seq_len, d), init="embed", name="te.pos")
    h = jnp.take(table, ids, axis=0) + pos[None, :, :]
    for layer in range(cfg.text_layers):
        t1 = layernorm(cur, h, d, name=f"te.{layer}.ln1")
        q = dense(cur, t1, d, d, name=f"te.{layer}.q")
        k = dense(cur, t1, d, d, name=f"te.{layer}.k")
        v = dense(cur, t1, d, d, name=f"te.{layer}.v")
        a = attention(q, k, v, max(1, cfg.num_heads // 2), use_pallas)
        h = h + dense(cur, a, d, d, name=f"te.{layer}.o")
        t2 = layernorm(cur, h, d, name=f"te.{layer}.ln2")
        ff = dense(cur, t2, d, 4 * d, name=f"te.{layer}.ff1")
        h = h + dense(cur, kref.silu_ref(ff), 4 * d, d,
                      name=f"te.{layer}.ff2")
    return layernorm(cur, h, d, name="te.lnf")


def vae_decoder(cur, cfg: ModelConfig, latent, use_pallas: bool = True):
    """Latent -> RGB image in [-1, 1].

    latent: [B, C, H, W] -> image [B, 3, H * 2**k, W * 2**k].
    """
    g = cfg.groupnorm_groups
    widths: Sequence[int] = list(cfg.vae_channels)
    while len(widths) < cfg.vae_upsamples:
        widths.append(widths[-1])

    ch = widths[0]
    h = conv2d(cur, latent, cfg.latent_channels, ch, 3, name="vae.in")
    h = h + conv2d(cur, gn_silu(cur, h, ch, g, use_pallas, name="vae.res.gns"),
                   ch, ch, 3, name="vae.res.conv")
    for i in range(cfg.vae_upsamples):
        nxt = widths[min(i, len(widths) - 1)]
        h = upsample(cur, h, ch, name=f"vae.up{i}")
        if nxt != ch:
            h = conv2d(cur, h, ch, nxt, 1, name=f"vae.ch{i}")
            ch = nxt
        h = h + conv2d(cur, gn_silu(cur, h, ch, g, use_pallas,
                                    name=f"vae.res{i}.gns"),
                       ch, ch, 3, name=f"vae.res{i}.conv")
    h = gn_silu(cur, h, ch, g, use_pallas, name="vae.out.gns")
    return jnp.tanh(conv2d(cur, h, ch, 3, 3, name="vae.out.conv"))
