"""L1: classifier-free guidance combine (Eq. 1) as a Pallas kernel.

    eps_hat = eps_u + s * (eps_c - eps_u)

A purely elementwise VPU kernel: the grid walks 128-wide tiles of the
flattened latent; the guidance scale rides along as a (1, 1) block so the
same compiled artifact serves any scale (the paper's §3.4 GS-tuning sweeps
change s at request time, not compile time).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128  # one VPU lane row


def _cfg_kernel(s_ref, u_ref, c_ref, o_ref):
    s = s_ref[0, 0]
    u = u_ref[...]
    c = c_ref[...]
    o_ref[...] = u + s * (c - u)


def _pick_tile(n: int, preferred: int = TILE) -> int:
    t = min(preferred, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def cfg_combine(eps_uncond, eps_cond, scale, *, interpret: bool = True):
    """Fused Eq.-1 combine over arbitrary (equal) shapes.

    eps_uncond / eps_cond: same shape; scale: scalar or [1] array.
    """
    assert eps_uncond.shape == eps_cond.shape
    shape = eps_uncond.shape
    n = 1
    for dim in shape:
        n *= dim
    t = _pick_tile(n)
    u = eps_uncond.reshape(n)
    c = eps_cond.reshape(n)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _cfg_kernel,
        grid=(n // t,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # guidance scale
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), eps_uncond.dtype),
        interpret=interpret,
    )(s, u, c)
    return out.reshape(shape)
