"""L1: flash-style attention as a Pallas kernel.

This is the UNet's compute hot-spot (self-attention over latent tokens and
cross-attention over the text context). The paper's system runs on
V100/CUDA where the HF pipeline dispatches cuBLAS GEMMs + softmax kernels;
the TPU re-think (DESIGN.md section 4) tiles Q into VMEM-resident blocks via
BlockSpec, streams K/V blocks through an online-softmax accumulator, and
shapes every contraction as an MXU-friendly matmul.

Executed with ``interpret=True`` so it lowers to plain HLO runnable on the
CPU PJRT backend (real-TPU lowering emits a Mosaic custom-call the CPU
plugin cannot execute — see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default VMEM tile sizes. At these blocks the per-program footprint is
#   q_blk (bq*d) + K,V (2*S*d) + acc (bq*d) + m,l (2*bq)   floats,
# far under the ~16 MiB VMEM budget for every preset (see DESIGN.md §Perf).
DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, scale: float):
    """One program instance: one (batch*head, q-block) tile.

    q_ref: [1, bq, d]; k_ref/v_ref: [1, S, d]; o_ref: [1, bq, d].
    Online softmax over K/V blocks (Milakov & Gimelshein / FlashAttention
    style): running max m, running sum l, rescaled accumulator acc.
    """
    q = q_ref[0].astype(jnp.float32) * scale            # [bq, d]
    bq, d = q.shape
    skv = k_ref.shape[1]
    nk = skv // block_k

    def body(i, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (0, pl.dslice(i * block_k, block_k),
                            slice(None))).astype(jnp.float32)   # [bk, d]
        v = pl.load(v_ref, (0, pl.dslice(i * block_k, block_k),
                            slice(None))).astype(jnp.float32)   # [bk, d]
        s = q @ k.T                                      # [bq, bk]  (MXU)
        m_new = jnp.maximum(m, s.max(axis=-1))           # [bq]
        p = jnp.exp(s - m_new[:, None])                  # [bq, bk]
        alpha = jnp.exp(m - m_new)                       # [bq]
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v           # [bq, d]  (MXU)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (>=1)."""
    b = min(preferred, n)
    while n % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K, interpret: bool = True):
    """Batched multi-head attention via the Pallas kernel.

    q: [BH, Sq, d]; k, v: [BH, Skv, d]  ->  [BH, Sq, d]
    BH is batch*heads flattened by the caller. Sq/Skv need not be equal
    (cross-attention). Block sizes are clamped to divisors of the sequence
    lengths so no masking is required.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert k.shape == (bh, skv, d) and v.shape == (bh, skv, d)
    bq = _pick_block(sq, block_q)
    bk = _pick_block(skv, block_k)
    scale = 1.0 / (d ** 0.5)

    grid = (bh, sq // bq)
    return pl.pallas_call(
        functools.partial(_attn_kernel, block_k=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),   # Q tile
            pl.BlockSpec((1, skv, d), lambda i, j: (i, 0, 0)),  # full K row
            pl.BlockSpec((1, skv, d), lambda i, j: (i, 0, 0)),  # full V row
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
