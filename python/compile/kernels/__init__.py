"""Pallas kernels (L1) + pure-jnp reference oracles.

Public surface used by the L2 model:
    flash_attention, cfg_combine, groupnorm_silu
and the oracles in ref.py used by pytest and the non-pallas model path.
"""

from .attention import flash_attention
from .cfg_combine import cfg_combine
from .groupnorm_silu import groupnorm_silu
from . import ref

__all__ = ["flash_attention", "cfg_combine", "groupnorm_silu", "ref"]
