"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain jax.numpy ops only. pytest (python/tests/) asserts the Pallas
interpret-mode outputs allclose against these across a hypothesis-driven
sweep of shapes and dtypes. These are also the fallback path the L2 model
uses when ``use_pallas=False`` (e.g. for fast shape tests).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, scale=None):
    """Scaled dot-product attention.

    q: [BH, Sq, d], k/v: [BH, Skv, d]  ->  [BH, Sq, d]
    """
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def cfg_combine_ref(eps_uncond, eps_cond, scale):
    """Classifier-free guidance combine — Eq. (1) of the paper:

        eps_hat = eps_u + s * (eps_c - eps_u)

    eps_*: any equal shape; scale: scalar (or [1]) guidance scale s.
    """
    s = jnp.asarray(scale).reshape(())
    return eps_uncond + s * (eps_cond - eps_uncond)


def silu_ref(x):
    """Numerically-stable SiLU, matching the fused kernel's activation."""
    return x * jnp.where(x >= 0, 1.0 / (1.0 + jnp.exp(-x)),
                         jnp.exp(x) / (1.0 + jnp.exp(x)))


def groupnorm_silu_ref(x, gamma, beta, groups, eps=1e-5):
    """Fused GroupNorm + SiLU.

    x: [B, C, H, W]; gamma/beta: [C]. Normalizes over each group's
    (C/groups, H, W) slab, applies affine, then SiLU.
    """
    b, c, h, w = x.shape
    assert c % groups == 0, (c, groups)
    xg = x.reshape(b, groups, c // groups, h, w).astype(jnp.float32)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    xn = (xg - mean) / jnp.sqrt(var + eps)
    xn = xn.reshape(b, c, h, w)
    y = xn * gamma.reshape(1, c, 1, 1) + beta.reshape(1, c, 1, 1)
    return silu_ref(y).astype(x.dtype)
