"""L1: fused GroupNorm + SiLU as a Pallas kernel.

Every ResBlock in the UNet (and the VAE decoder head) does
GroupNorm -> SiLU -> conv. Fusing the normalization statistics, affine and
activation into one VMEM-resident pass removes two HBM round-trips per
block — the TPU analogue of the fused CUDA groupnorm kernels in the
DeepSpeed inference pipeline the paper built on.

Grid: one program per (batch, group). The group's (C/G, H*W) slab plus its
gamma/beta slice live in VMEM; stats are computed in f32.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gn_silu_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[0, 0].astype(jnp.float32)        # [Cg, HW]
    mean = x.mean()
    var = ((x - mean) ** 2).mean()
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    y = xn * g_ref[0][:, None] + b_ref[0][:, None]
    # numerically-stable SiLU
    sig = jnp.where(y >= 0, 1.0 / (1.0 + jnp.exp(-y)),
                    jnp.exp(y) / (1.0 + jnp.exp(y)))
    o_ref[0, 0] = (y * sig).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("groups", "eps", "interpret"))
def groupnorm_silu(x, gamma, beta, *, groups: int, eps: float = 1e-5,
                   interpret: bool = True):
    """Fused GroupNorm+SiLU.  x: [B, C, H, W]; gamma/beta: [C]."""
    b, c, h, w = x.shape
    assert c % groups == 0, (c, groups)
    cg = c // groups
    hw = h * w
    xg = x.reshape(b, groups, cg, hw)
    gg = gamma.astype(jnp.float32).reshape(groups, cg)
    bg = beta.astype(jnp.float32).reshape(groups, cg)

    out = pl.pallas_call(
        functools.partial(_gn_silu_kernel, eps=eps),
        grid=(b, groups),
        in_specs=[
            pl.BlockSpec((1, 1, cg, hw), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, cg), lambda i, j: (j, 0)),
            pl.BlockSpec((1, cg), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cg, hw), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, groups, cg, hw), x.dtype),
        interpret=interpret,
    )(xg, gg, bg)
    return out.reshape(b, c, h, w)
