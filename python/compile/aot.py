"""AOT compiler: lower every compute graph to HLO text + weight blobs.

Run once at build time (``make artifacts``); the rust coordinator then
serves forever without Python. For each model preset this emits, under
``artifacts/<preset>/``:

    unet_b{1,2,4}.hlo.txt     UNet eps-prediction at batch sizes 1/2/4
                              (bucketed dynamic batching — DESIGN.md §5)
    text_encoder.hlo.txt      token ids -> cross-attention context
    vae_decoder.hlo.txt       latent -> RGB image
    cfg_combine_b{1,2,4}.hlo.txt  Eq.-1 combine (Pallas kernel artifact)
    unet.params.bin / text_encoder.params.bin / vae_decoder.params.bin
                              flat little-endian f32 weight vectors
    manifest.json             shapes, param counts, source hash

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

The emission is skipped when ``manifest.json`` already records the current
source hash (``make artifacts`` is a no-op on unchanged inputs).
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, params
from .kernels import cfg_combine

BATCH_SIZES = (1, 2, 4)


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(fn, *arg_specs) -> str:
    """jit-lower ``fn`` and convert to HLO text via stablehlo."""
    lowered = jax.jit(fn).lower(*arg_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def source_hash() -> str:
    """Hash of every python source feeding the artifacts."""
    h = hashlib.sha256()
    pkg = os.path.dirname(__file__)
    for root, _, files in os.walk(pkg):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def _shape_entry(name, dtype, shape):
    return {"name": name, "dtype": dtype, "shape": list(shape)}


# ---------------------------------------------------------------------------
# per-preset emission
# ---------------------------------------------------------------------------

def emit_preset(cfg: configs.ModelConfig, out_root: str,
                batch_sizes=BATCH_SIZES) -> dict:
    out_dir = os.path.join(out_root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    C, H, W = cfg.latent_shape
    S, D = cfg.seq_len, cfg.text_dim
    artifacts = {}

    def write(name: str, text: str):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {path} ({len(text) / 1e3:.0f} kB)")

    def write_params(name: str, flat: jax.Array) -> int:
        arr = np.asarray(flat, dtype="<f4")
        path = os.path.join(out_dir, name)
        arr.tofile(path)
        print(f"  wrote {path} ({arr.size:,} params)")
        return int(arr.size)

    # ---- UNet ------------------------------------------------------------
    def unet_example(b):
        return (spec((b, C, H, W)), spec((b,)), spec((b, S, D)))

    t0 = time.time()
    uflat = params.init_flat(
        lambda cur: model.unet(cur, cfg, jnp.zeros((1, C, H, W)),
                               jnp.zeros((1,)), jnp.zeros((1, S, D))),
        cfg.seed)
    pu = write_params("unet.params.bin", uflat)
    for b in batch_sizes:
        def unet_fn(p, lat, t, ctx):
            return (model.unet(params.ParamCursor(flat=p), cfg, lat, t, ctx),)
        text = to_hlo_text(unet_fn, spec((pu,)), *unet_example(b))
        name = f"unet_b{b}"
        write(f"{name}.hlo.txt", text)
        artifacts[name] = {
            "hlo": f"{name}.hlo.txt", "params": "unet.params.bin",
            "param_count": pu, "batch": b,
            "inputs": [_shape_entry("params", "f32", (pu,)),
                       _shape_entry("latent", "f32", (b, C, H, W)),
                       _shape_entry("t", "f32", (b,)),
                       _shape_entry("ctx", "f32", (b, S, D))],
            "outputs": [_shape_entry("eps", "f32", (b, C, H, W))],
        }
    print(f"  unet done in {time.time() - t0:.1f}s")

    # ---- text encoder ------------------------------------------------------
    ids0 = jnp.zeros((1, S), jnp.int32)
    tflat = params.init_flat(
        lambda cur: model.text_encoder(cur, cfg, ids0), cfg.seed + 1)
    pt = write_params("text_encoder.params.bin", tflat)

    def te_fn(p, ids):
        return (model.text_encoder(params.ParamCursor(flat=p), cfg, ids),)

    write("text_encoder.hlo.txt",
          to_hlo_text(te_fn, spec((pt,)), spec((1, S), jnp.int32)))
    artifacts["text_encoder"] = {
        "hlo": "text_encoder.hlo.txt", "params": "text_encoder.params.bin",
        "param_count": pt, "batch": 1,
        "inputs": [_shape_entry("params", "f32", (pt,)),
                   _shape_entry("ids", "i32", (1, S))],
        "outputs": [_shape_entry("ctx", "f32", (1, S, D))],
    }

    # ---- VAE decoder -------------------------------------------------------
    lat0 = jnp.zeros((1, C, H, W))
    vflat = params.init_flat(
        lambda cur: model.vae_decoder(cur, cfg, lat0), cfg.seed + 2)
    pv = write_params("vae_decoder.params.bin", vflat)

    def vae_fn(p, lat):
        return (model.vae_decoder(params.ParamCursor(flat=p), cfg, lat),)

    img = cfg.image_size
    write("vae_decoder.hlo.txt",
          to_hlo_text(vae_fn, spec((pv,)), spec((1, C, H, W))))
    artifacts["vae_decoder"] = {
        "hlo": "vae_decoder.hlo.txt", "params": "vae_decoder.params.bin",
        "param_count": pv, "batch": 1,
        "inputs": [_shape_entry("params", "f32", (pv,)),
                   _shape_entry("latent", "f32", (1, C, H, W))],
        "outputs": [_shape_entry("image", "f32", (1, 3, img, img))],
    }

    # ---- CFG combine (the Eq.-1 Pallas kernel as its own artifact) ---------
    for b in batch_sizes:
        def cfg_fn(u, c, s):
            return (cfg_combine(u, c, s),)
        name = f"cfg_combine_b{b}"
        write(f"{name}.hlo.txt",
              to_hlo_text(cfg_fn, spec((b, C, H, W)), spec((b, C, H, W)),
                          spec((1,))))
        artifacts[name] = {
            "hlo": f"{name}.hlo.txt", "params": None, "param_count": 0,
            "batch": b,
            "inputs": [_shape_entry("eps_uncond", "f32", (b, C, H, W)),
                       _shape_entry("eps_cond", "f32", (b, C, H, W)),
                       _shape_entry("scale", "f32", (1,))],
            "outputs": [_shape_entry("eps_hat", "f32", (b, C, H, W))],
        }

    manifest = {
        "version": 1,
        "preset": cfg.name,
        "source_hash": source_hash(),
        "model": {
            "latent_channels": C, "latent_size": H,
            "image_size": cfg.image_size, "seq_len": S, "text_dim": D,
            "vocab_size": cfg.vocab_size, "seed": cfg.seed,
            "batch_sizes": list(batch_sizes),
        },
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def up_to_date(cfg: configs.ModelConfig, out_root: str) -> bool:
    path = os.path.join(out_root, cfg.name, "manifest.json")
    try:
        with open(path) as f:
            m = json.load(f)
        return m.get("source_hash") == source_hash()
    except (OSError, ValueError):
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output root")
    ap.add_argument("--presets", default="tiny,small",
                    help="comma-separated preset names (tiny,small,base)")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if manifests are current")
    args = ap.parse_args(argv)

    for name in args.presets.split(","):
        cfg = configs.preset(name.strip())
        if not args.force and up_to_date(cfg, args.out):
            print(f"preset {cfg.name}: up to date, skipping")
            continue
        print(f"preset {cfg.name}: emitting artifacts...")
        t0 = time.time()
        emit_preset(cfg, args.out)
        print(f"preset {cfg.name}: done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
