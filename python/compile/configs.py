"""Model size presets for the selective-guidance stack.

The paper runs Stable Diffusion v1.x (860M-param UNet, 512x512 output).
We reproduce the *architecture family* (latent-space UNet with ResNet
blocks + self/cross-attention transformer blocks, CLIP-like text encoder,
conv VAE decoder) at three reduced scales so the whole stack runs on the
CPU PJRT backend. See DESIGN.md section 3 for the substitution ledger.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters shared by L2 (jax model) and recorded in the
    artifact manifest for the rust coordinator."""

    name: str
    # --- latent space -----------------------------------------------------
    latent_channels: int  # C of the UNet input/output
    latent_size: int      # H == W of the latent
    # --- UNet -------------------------------------------------------------
    channels: Tuple[int, ...]      # per-resolution channel widths
    blocks_per_level: int          # ResBlocks per resolution level
    attn_levels: Tuple[int, ...]   # level indices that get transformer blocks
    num_heads: int
    time_embed_dim: int
    # --- text encoder -----------------------------------------------------
    vocab_size: int
    seq_len: int       # S: padded token count
    text_dim: int      # D: context embedding dim (== cross-attn kv dim)
    text_layers: int
    # --- VAE decoder ------------------------------------------------------
    vae_channels: Tuple[int, ...]  # decoder widths, latent -> image
    vae_upsamples: int             # number of 2x upsample stages
    # --- misc ---------------------------------------------------------
    groupnorm_groups: int = 8
    seed: int = 0

    @property
    def image_size(self) -> int:
        return self.latent_size * (2 ** self.vae_upsamples)

    @property
    def latent_shape(self) -> Tuple[int, int, int]:
        """(C, H, W) of a single latent sample."""
        return (self.latent_channels, self.latent_size, self.latent_size)


TINY = ModelConfig(
    name="tiny",
    latent_channels=4,
    latent_size=8,
    channels=(32, 64),
    blocks_per_level=1,
    attn_levels=(1,),
    num_heads=2,
    time_embed_dim=64,
    vocab_size=1024,
    seq_len=8,
    text_dim=32,
    text_layers=1,
    vae_channels=(32, 16),
    vae_upsamples=2,
)

SMALL = ModelConfig(
    name="small",
    latent_channels=4,
    latent_size=16,
    channels=(32, 64, 96),
    blocks_per_level=1,
    attn_levels=(1, 2),
    num_heads=4,
    time_embed_dim=96,
    vocab_size=2048,
    seq_len=16,
    text_dim=64,
    text_layers=2,
    vae_channels=(48, 24),
    vae_upsamples=2,
)

BASE = ModelConfig(
    name="base",
    latent_channels=4,
    latent_size=24,
    channels=(48, 96, 144),
    blocks_per_level=2,
    attn_levels=(1, 2),
    num_heads=4,
    time_embed_dim=144,
    vocab_size=4096,
    seq_len=24,
    text_dim=96,
    text_layers=2,
    vae_channels=(64, 32),
    vae_upsamples=3,
)

PRESETS = {c.name: c for c in (TINY, SMALL, BASE)}


def preset(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; have {sorted(PRESETS)}") from None
