"""Deterministic parameter store for the L2 model.

The serving architecture keeps weights OUT of the HLO text: every artifact
takes a single flat ``f32[P]`` parameter vector as its first input, and the
rust runtime feeds it from ``artifacts/<name>.params.bin`` (raw little-
endian f32). This mirrors a real deployment (program file + weights file)
and keeps the HLO artifacts small and fast to parse.

``ParamCursor`` realizes this: the model code calls ``cursor.take(shape,
init)`` in a fixed order. In *init* mode the cursor draws the value from a
seeded jax PRNG stream; in *apply* mode it slices the same range out of the
flat vector. One code path defines both the initializer and the layout, so
they cannot drift.

The paper used trained SD v1.x weights; we substitute deterministic seeded
initialization (DESIGN.md section 3) — quality *deltas* between guidance
policies stay measurable, which is what the paper's experiments compare.
"""

import math
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


class ParamCursor:
    """Sequential parameter declaration / consumption.

    init mode:  ParamCursor(key=jax.random.PRNGKey(seed)) — ``take`` draws
                fresh values; ``flatten()`` returns the full f32[P] vector.
    apply mode: ParamCursor(flat=params_vector) — ``take`` slices the next
                range out of ``flat``.
    """

    def __init__(self, flat: Optional[jax.Array] = None,
                 key: Optional[jax.Array] = None):
        assert (flat is None) != (key is None), "exactly one of flat/key"
        self.flat = flat
        self.key = key
        self.offset = 0
        self.names: List[Tuple[str, Tuple[int, ...], int]] = []

    # ------------------------------------------------------------------
    def take(self, shape, init: str = "normal", fan_in: Optional[int] = None,
             scale: float = 1.0, name: str = "") -> jax.Array:
        """Declare/consume one parameter tensor.

        init: 'normal' (scaled by 1/sqrt(fan_in) if given), 'zeros', 'ones',
              'embed' (N(0, 0.02)).
        """
        shape = tuple(int(d) for d in shape)
        n = _prod(shape)
        self.names.append((name, shape, self.offset))
        if self.flat is not None:
            arr = lax.slice(self.flat, (self.offset,), (self.offset + n,))
            out = arr.reshape(shape)
        else:
            self.key, sub = jax.random.split(self.key)
            if init == "zeros":
                out = jnp.zeros(shape, jnp.float32)
            elif init == "ones":
                out = jnp.ones(shape, jnp.float32)
            elif init == "embed":
                out = 0.02 * jax.random.normal(sub, shape, jnp.float32)
            elif init == "normal":
                std = scale / math.sqrt(fan_in) if fan_in else scale
                out = std * jax.random.normal(sub, shape, jnp.float32)
            else:
                raise ValueError(f"unknown init {init!r}")
            self._init_parts.append(out.reshape(-1))
        self.offset += n
        return out

    # init-mode helpers ---------------------------------------------------
    @property
    def _init_parts(self) -> List[jax.Array]:
        if not hasattr(self, "_parts"):
            self._parts: List[jax.Array] = []
        return self._parts

    def flatten(self) -> jax.Array:
        assert self.flat is None, "flatten() only valid in init mode"
        if not self._init_parts:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(self._init_parts)

    @property
    def size(self) -> int:
        return self.offset


def count_params(model_fn, *example_args) -> int:
    """Trace ``model_fn(cursor, *args)`` in init mode and return P."""
    cur = ParamCursor(key=jax.random.PRNGKey(0))
    jax.eval_shape(lambda: model_fn(cur, *example_args))
    return cur.size


def init_flat(model_fn, seed: int, *example_args) -> jax.Array:
    """Materialize the flat parameter vector for ``model_fn``."""
    cur = ParamCursor(key=jax.random.PRNGKey(seed))
    model_fn(cur, *example_args)
    return cur.flatten()
