"""AOT pipeline: HLO text emission, manifests, skip-if-unchanged."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, configs


def test_to_hlo_text_smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    text = aot.to_hlo_text(fn, aot.spec((2, 2)), aot.spec((2, 2)))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "dot(" in text or "dot " in text


def test_to_hlo_text_pallas_kernel():
    """A pallas interpret-mode kernel must lower to plain HLO (no
    custom-call), otherwise the rust CPU client cannot execute it."""
    from compile.kernels import cfg_combine

    def fn(u, c, s):
        return (cfg_combine(u, c, s),)

    text = aot.to_hlo_text(fn, aot.spec((1, 4, 8, 8)),
                           aot.spec((1, 4, 8, 8)), aot.spec((1,)))
    assert text.startswith("HloModule")
    assert "custom-call" not in text


def test_source_hash_stable_and_sensitive(tmp_path):
    h1 = aot.source_hash()
    h2 = aot.source_hash()
    assert h1 == h2
    assert len(h1) == 64


def test_up_to_date_logic(tmp_path):
    cfg = configs.preset("tiny")
    root = str(tmp_path)
    assert not aot.up_to_date(cfg, root)           # nothing on disk
    d = os.path.join(root, "tiny")
    os.makedirs(d)
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"source_hash": "stale"}, f)
    assert not aot.up_to_date(cfg, root)           # wrong hash
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump({"source_hash": aot.source_hash()}, f)
    assert aot.up_to_date(cfg, root)               # current


def test_built_manifest_structure():
    """Validate the real manifest the rust side will parse (requires
    `make artifacts` to have run; skipped otherwise)."""
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "tiny", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert m["preset"] == "tiny"
    mod = m["model"]
    for key in ("latent_channels", "latent_size", "image_size", "seq_len",
                "text_dim", "vocab_size", "batch_sizes"):
        assert key in mod, key
    arts = m["artifacts"]
    for b in mod["batch_sizes"]:
        assert f"unet_b{b}" in arts
        assert f"cfg_combine_b{b}" in arts
    assert "text_encoder" in arts and "vae_decoder" in arts
    art_dir = os.path.dirname(path)
    for a in arts.values():
        assert os.path.exists(os.path.join(art_dir, a["hlo"]))
        if a["params"]:
            pb = os.path.join(art_dir, a["params"])
            assert os.path.getsize(pb) == 4 * a["param_count"]


def test_manifest_shapes_consistent():
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "tiny", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        m = json.load(f)
    cfg = configs.preset("tiny")
    C, H, W = cfg.latent_shape
    u1 = m["artifacts"]["unet_b1"]
    assert u1["inputs"][1]["shape"] == [1, C, H, W]
    assert u1["outputs"][0]["shape"] == [1, C, H, W]
    te = m["artifacts"]["text_encoder"]
    assert te["outputs"][0]["shape"] == [1, cfg.seq_len, cfg.text_dim]
    vae = m["artifacts"]["vae_decoder"]
    assert vae["outputs"][0]["shape"] == [1, 3, cfg.image_size,
                                          cfg.image_size]
