"""HLO cost profiler: sanity of the §Perf numbers."""

import jax.numpy as jnp
import pytest

from compile import aot, configs, profile


def test_analyze_artifact_simple_matmul():
    def fn(x, y):
        return (x @ y,)

    # 64x64 @ 64x64 matmul = 2*64^3 = 524288 flops
    r = profile.analyze_artifact(fn, (aot.spec((64, 64)), aot.spec((64, 64))))
    assert r["flops"] == pytest.approx(2 * 64**3, rel=0.01)
    assert r["bytes_accessed"] > 3 * 64 * 64 * 4 * 0.9
    assert r["arithmetic_intensity"] > 0


def test_analyze_artifact_elementwise_low_intensity():
    def fn(x):
        return (x + 1.0,)

    r = profile.analyze_artifact(fn, (aot.spec((1024,)),))
    # one flop per element, ~8 bytes moved per element
    assert r["arithmetic_intensity"] < 1.0


def test_hlo_text_histogram():
    text = """HloModule m
ENTRY %main (x: f32[2,2]) -> f32[2,2] {
  %x = f32[2,2] parameter(0)
  %c = f32[2,2] constant({...})
  ROOT %add = f32[2,2] add(%x, %c)
}
"""
    ops = profile.analyze_hlo_text(text)
    assert ops.get("parameter") == 1
    assert ops.get("add") == 1


@pytest.mark.slow
def test_profile_tiny_preset(tmp_path):
    cfg = configs.preset("tiny")
    r = profile.profile_preset(cfg, str(tmp_path))
    arts = r["artifacts"]
    # dual/optimized FLOP ratio must match the paper's 2x model closely
    ratio = r["derived"]["dual_step_over_optimized_fused"]
    assert 1.8 < ratio < 2.2, ratio
    # UNet dominates the per-step cost
    assert arts["unet_b1"]["flops"] > 10 * arts["text_encoder"]["flops"]
    assert (tmp_path / "tiny" / "profile.json").exists()
