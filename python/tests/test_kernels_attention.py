"""L1 attention kernel vs pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("bh,sq,skv,d", [
    (1, 4, 4, 8), (2, 16, 16, 16), (6, 16, 24, 8),
    (4, 64, 8, 32), (1, 8, 64, 4), (3, 12, 20, 16),
])
def test_matches_ref(bh, sq, skv, d):
    rng = np.random.default_rng(hash((bh, sq, skv, d)) % 2**32)
    q, k, v = (_rand(rng, (bh, sq, d)), _rand(rng, (bh, skv, d)),
               _rand(rng, (bh, skv, d)))
    out = flash_attention(q, k, v)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(
    bh=st.integers(1, 4),
    sq=st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32]),
    skv=st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32]),
    d=st.sampled_from([2, 4, 8, 16, 32]),
    bq=st.sampled_from([2, 4, 8, 16]),
    bk=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(bh, sq, skv, d, bq, bk, seed):
    """Shape/block sweep: block sizes are clamped to divisors internally,
    so every combination must agree with the oracle."""
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (bh, sq, d)), _rand(rng, (bh, skv, d)),
               _rand(rng, (bh, skv, d)))
    out = flash_attention(q, k, v, block_q=bq, block_k=bk)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=5e-5, atol=5e-5)


def test_softmax_rows_sum_to_one_property():
    """With v = identity basis stacked, output rows are the softmax probs
    themselves; they must be a distribution."""
    bh, s, d = 1, 8, 8
    rng = np.random.default_rng(0)
    q = _rand(rng, (bh, s, d))
    k = _rand(rng, (bh, s, d))
    v = jnp.eye(s, d)[None, :, :]
    out = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(out) >= -1e-6).all()


def test_uniform_keys_average_values():
    """Constant keys -> uniform attention -> output == mean of values."""
    bh, sq, skv, d = 2, 4, 16, 8
    rng = np.random.default_rng(1)
    q = _rand(rng, (bh, sq, d))
    k = jnp.ones((bh, skv, d))
    v = _rand(rng, (bh, skv, d))
    out = flash_attention(q, k, v)
    exp = np.broadcast_to(np.asarray(v).mean(1, keepdims=True),
                          (bh, sq, d))
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_extreme_logits_stable():
    """Online softmax must not overflow with large-magnitude logits."""
    bh, s, d = 1, 8, 4
    q = jnp.full((bh, s, d), 50.0)
    k = jnp.full((bh, s, d), 50.0)
    rng = np.random.default_rng(2)
    v = _rand(rng, (bh, s, d))
    out = np.asarray(flash_attention(q, k, v))
    assert np.isfinite(out).all()
    exp = np.asarray(v).mean(1, keepdims=True)
    np.testing.assert_allclose(out, np.broadcast_to(exp, out.shape),
                               rtol=1e-4, atol=1e-4)


def test_bfloat16_input():
    bh, s, d = 2, 16, 8
    rng = np.random.default_rng(3)
    q = _rand(rng, (bh, s, d)).astype(jnp.bfloat16)
    k = _rand(rng, (bh, s, d)).astype(jnp.bfloat16)
    v = _rand(rng, (bh, s, d)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v)
    assert out.dtype == jnp.bfloat16
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out.astype(np.float32),
                               exp.astype(np.float32), rtol=3e-2, atol=3e-2)


def test_permutation_equivariance_in_kv():
    """Attention is invariant to permuting K/V jointly."""
    bh, sq, skv, d = 1, 8, 16, 8
    rng = np.random.default_rng(4)
    q, k, v = (_rand(rng, (bh, sq, d)), _rand(rng, (bh, skv, d)),
               _rand(rng, (bh, skv, d)))
    perm = rng.permutation(skv)
    out1 = flash_attention(q, k, v)
    out2 = flash_attention(q, k[:, perm], v[:, perm])
    np.testing.assert_allclose(out1, out2, rtol=3e-5, atol=3e-5)
