"""L2 model: shapes, pallas-vs-ref path equivalence, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, params


def _inputs(cfg, batch=1, seed=0):
    rng = np.random.default_rng(seed)
    C, H, W = cfg.latent_shape
    lat = jnp.asarray(rng.standard_normal((batch, C, H, W),
                                          dtype=np.float32))
    t = jnp.asarray(rng.uniform(0, 1000, batch).astype(np.float32))
    ctx = jnp.asarray(rng.standard_normal(
        (batch, cfg.seq_len, cfg.text_dim), dtype=np.float32))
    return lat, t, ctx


@pytest.mark.parametrize("name", ["tiny", "small", "base"])
@pytest.mark.parametrize("batch", [1, 2])
def test_unet_shapes(name, batch):
    cfg = configs.preset(name)
    lat, t, ctx = _inputs(cfg, batch)

    def fn(cur):
        return model.unet(cur, cfg, lat, t, ctx, use_pallas=False)

    out = jax.eval_shape(
        lambda: fn(params.ParamCursor(key=jax.random.PRNGKey(0))))
    assert out.shape == lat.shape
    assert out.dtype == jnp.float32


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_text_encoder_shapes(name):
    cfg = configs.preset(name)
    ids = jnp.zeros((1, cfg.seq_len), jnp.int32)
    out = jax.eval_shape(lambda: model.text_encoder(
        params.ParamCursor(key=jax.random.PRNGKey(0)), cfg, ids,
        use_pallas=False))
    assert out.shape == (1, cfg.seq_len, cfg.text_dim)


@pytest.mark.parametrize("name", ["tiny", "small", "base"])
def test_vae_shapes(name):
    cfg = configs.preset(name)
    C, H, W = cfg.latent_shape
    lat = jnp.zeros((1, C, H, W))
    out = jax.eval_shape(lambda: model.vae_decoder(
        params.ParamCursor(key=jax.random.PRNGKey(0)), cfg, lat,
        use_pallas=False))
    assert out.shape == (1, 3, cfg.image_size, cfg.image_size)


def test_unet_pallas_matches_ref_path():
    """The L1-kernel path and the pure-jnp path must agree through the
    whole UNet (the end-to-end kernel-correctness check)."""
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg)
    flat = params.init_flat(
        lambda cur: model.unet(cur, cfg, lat, t, ctx, use_pallas=False),
        cfg.seed)
    out_ref = model.unet(params.ParamCursor(flat=flat), cfg, lat, t, ctx,
                         use_pallas=False)
    out_pal = model.unet(params.ParamCursor(flat=flat), cfg, lat, t, ctx,
                         use_pallas=True)
    np.testing.assert_allclose(out_pal, out_ref, rtol=2e-4, atol=2e-4)


def test_vae_pallas_matches_ref_path():
    cfg = configs.preset("tiny")
    C, H, W = cfg.latent_shape
    rng = np.random.default_rng(7)
    lat = jnp.asarray(rng.standard_normal((1, C, H, W), dtype=np.float32))
    flat = params.init_flat(
        lambda cur: model.vae_decoder(cur, cfg, lat, use_pallas=False),
        cfg.seed + 2)
    a = model.vae_decoder(params.ParamCursor(flat=flat), cfg, lat,
                          use_pallas=False)
    b = model.vae_decoder(params.ParamCursor(flat=flat), cfg, lat,
                          use_pallas=True)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_param_layout_stable_between_modes():
    """Init mode and apply mode must declare identical layouts."""
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg)

    def fn(cur):
        return model.unet(cur, cfg, lat, t, ctx, use_pallas=False)

    cur_init = params.ParamCursor(key=jax.random.PRNGKey(0))
    fn(cur_init)
    flat = cur_init.flatten()
    assert flat.shape == (cur_init.size,)

    cur_apply = params.ParamCursor(flat=flat)
    fn(cur_apply)
    assert cur_apply.size == cur_init.size
    assert [(n, s) for n, s, _ in cur_apply.names] == \
           [(n, s) for n, s, _ in cur_init.names]


def test_init_deterministic():
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg)

    def fn(cur):
        return model.unet(cur, cfg, lat, t, ctx, use_pallas=False)

    f1 = params.init_flat(fn, cfg.seed)
    f2 = params.init_flat(fn, cfg.seed)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    f3 = params.init_flat(fn, cfg.seed + 1)
    assert not np.allclose(np.asarray(f1), np.asarray(f3))


def test_unet_conditioning_matters():
    """Different contexts must produce different noise predictions —
    otherwise CFG (and the paper's whole premise) is vacuous."""
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg)
    rng = np.random.default_rng(9)
    ctx2 = jnp.asarray(rng.standard_normal(ctx.shape, dtype=np.float32))
    flat = params.init_flat(
        lambda cur: model.unet(cur, cfg, lat, t, ctx, use_pallas=False),
        cfg.seed)
    e1 = model.unet(params.ParamCursor(flat=flat), cfg, lat, t, ctx,
                    use_pallas=False)
    e2 = model.unet(params.ParamCursor(flat=flat), cfg, lat, t, ctx2,
                    use_pallas=False)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_unet_timestep_matters():
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg)
    flat = params.init_flat(
        lambda cur: model.unet(cur, cfg, lat, t, ctx, use_pallas=False),
        cfg.seed)
    e1 = model.unet(params.ParamCursor(flat=flat), cfg, lat,
                    jnp.asarray([10.0]), ctx, use_pallas=False)
    e2 = model.unet(params.ParamCursor(flat=flat), cfg, lat,
                    jnp.asarray([900.0]), ctx, use_pallas=False)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_batch_consistency():
    """Running two samples in one batch == running them separately."""
    cfg = configs.preset("tiny")
    lat, t, ctx = _inputs(cfg, batch=2)
    flat = params.init_flat(
        lambda cur: model.unet(cur, cfg, lat[:1], t[:1], ctx[:1],
                               use_pallas=False), cfg.seed)
    both = model.unet(params.ParamCursor(flat=flat), cfg, lat, t, ctx,
                      use_pallas=False)
    one = model.unet(params.ParamCursor(flat=flat), cfg, lat[:1], t[:1],
                     ctx[:1], use_pallas=False)
    two = model.unet(params.ParamCursor(flat=flat), cfg, lat[1:], t[1:],
                     ctx[1:], use_pallas=False)
    np.testing.assert_allclose(both[0], one[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(both[1], two[0], rtol=1e-4, atol=1e-4)
