"""L1 fused GroupNorm+SiLU kernel vs oracle + normalization invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import groupnorm_silu
from compile.kernels import ref


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(scale * rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("b,c,h,w,groups", [
    (1, 32, 8, 8, 8), (2, 64, 4, 4, 8), (4, 16, 8, 8, 8),
    (1, 48, 16, 16, 8), (2, 24, 8, 8, 8), (1, 8, 2, 2, 4),
])
def test_matches_ref(b, c, h, w, groups):
    rng = np.random.default_rng(hash((b, c, h, w, groups)) % 2**32)
    x = _rand(rng, (b, c, h, w))
    g = _rand(rng, (c,))
    be = _rand(rng, (c,))
    out = groupnorm_silu(x, g, be, groups=groups)
    exp = ref.groupnorm_silu_ref(x, g, be, groups)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    cg=st.integers(1, 8),       # channels per group
    groups=st.sampled_from([1, 2, 4, 8]),
    hw=st.sampled_from([1, 2, 4, 8]),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(b, cg, groups, hw, scale, seed):
    c = cg * groups
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, c, hw, hw), scale)
    g = _rand(rng, (c,))
    be = _rand(rng, (c,))
    out = groupnorm_silu(x, g, be, groups=groups)
    exp = ref.groupnorm_silu_ref(x, g, be, groups)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


def test_unit_affine_statistics():
    """gamma=1, beta=0: pre-activation is zero-mean unit-var per group, so
    silu(y) has the silu(N(0,1)) distribution; check via inverse mapping
    on a big sample: E[y] ~ 0 within tolerance."""
    rng = np.random.default_rng(0)
    x = _rand(rng, (1, 32, 16, 16), 5.0)
    out = np.asarray(groupnorm_silu(x, jnp.ones(32), jnp.zeros(32), groups=8))
    # silu is monotone; median of silu(N(0,1)) = silu(0) = 0
    assert abs(np.median(out)) < 0.05


def test_shift_invariance():
    """GroupNorm removes per-group additive shifts of the input."""
    rng = np.random.default_rng(1)
    x = _rand(rng, (2, 16, 8, 8))
    g, be = _rand(rng, (16,)), _rand(rng, (16,))
    out1 = groupnorm_silu(x, g, be, groups=4)
    out2 = groupnorm_silu(x + 3.7, g, be, groups=4)
    np.testing.assert_allclose(out1, out2, rtol=2e-4, atol=2e-4)


def test_scale_invariance():
    """...and multiplicative scalings."""
    rng = np.random.default_rng(2)
    x = _rand(rng, (1, 32, 4, 4))
    g, be = _rand(rng, (32,)), _rand(rng, (32,))
    out1 = groupnorm_silu(x, g, be, groups=8)
    out2 = groupnorm_silu(x * 11.0, g, be, groups=8)
    np.testing.assert_allclose(out1, out2, rtol=5e-4, atol=5e-4)


def test_groups_partition_independence():
    """Changing data in group 1 must not affect group 0's output."""
    rng = np.random.default_rng(3)
    x = np.asarray(_rand(rng, (1, 16, 4, 4)))
    g, be = jnp.ones(16), jnp.zeros(16)
    out1 = np.asarray(groupnorm_silu(jnp.asarray(x), g, be, groups=2))
    x2 = x.copy()
    x2[:, 8:] *= -2.0
    out2 = np.asarray(groupnorm_silu(jnp.asarray(x2), g, be, groups=2))
    np.testing.assert_allclose(out1[:, :8], out2[:, :8], rtol=1e-5,
                               atol=1e-5)
    assert not np.allclose(out1[:, 8:], out2[:, 8:])
