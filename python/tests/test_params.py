"""ParamCursor: layout contract between init and apply modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.params import ParamCursor, count_params, init_flat


def toy_model(cur, x):
    w1 = cur.take((4, 8), init="normal", fan_in=4, name="w1")
    b1 = cur.take((8,), init="zeros", name="b1")
    g = cur.take((8,), init="ones", name="g")
    emb = cur.take((16, 8), init="embed", name="emb")
    return (x @ w1 + b1) * g + emb[0]


def test_count_matches_manual():
    assert count_params(toy_model, jnp.zeros((2, 4))) == 4 * 8 + 8 + 8 + 16 * 8


def test_flatten_apply_roundtrip():
    flat = init_flat(toy_model, 0, jnp.zeros((2, 4)))
    cur = ParamCursor(flat=flat)
    x = jnp.ones((2, 4))
    out = toy_model(cur, x)
    assert cur.size == flat.shape[0]
    # recompute manually from flat slices
    w1 = np.asarray(flat[:32]).reshape(4, 8)
    b1 = np.asarray(flat[32:40])
    g = np.asarray(flat[40:48])
    emb = np.asarray(flat[48:]).reshape(16, 8)
    exp = (np.ones((2, 4)) @ w1 + b1) * g + emb[0]
    np.testing.assert_allclose(out, exp, rtol=1e-6)


def test_init_kinds():
    cur = ParamCursor(key=jax.random.PRNGKey(0))
    z = cur.take((5,), init="zeros")
    o = cur.take((5,), init="ones")
    n = cur.take((1000,), init="normal", fan_in=4)
    e = cur.take((1000,), init="embed")
    np.testing.assert_array_equal(np.asarray(z), 0.0)
    np.testing.assert_array_equal(np.asarray(o), 1.0)
    assert abs(float(jnp.std(n)) - 0.5) < 0.05       # 1/sqrt(4)
    assert abs(float(jnp.std(e)) - 0.02) < 0.005


def test_offsets_sequential():
    cur = ParamCursor(key=jax.random.PRNGKey(0))
    cur.take((3, 3), name="a")
    cur.take((7,), name="b")
    names = {n: off for n, _, off in cur.names}
    assert names == {"a": 0, "b": 9}
    assert cur.size == 16


def test_apply_requires_exact_budget():
    """Consuming more than the flat vector holds raises (slice OOB)."""
    flat = jnp.zeros((10,))
    cur = ParamCursor(flat=flat)
    cur.take((10,))
    with pytest.raises(Exception):
        jax.eval_shape(lambda: cur.take((1,)))


def test_exactly_one_mode():
    with pytest.raises(AssertionError):
        ParamCursor()
    with pytest.raises(AssertionError):
        ParamCursor(flat=jnp.zeros(1), key=jax.random.PRNGKey(0))


def test_flatten_only_in_init_mode():
    cur = ParamCursor(flat=jnp.zeros(4))
    cur.take((4,))
    with pytest.raises(AssertionError):
        cur.flatten()
