"""L1 CFG-combine kernel (Eq. 1) vs oracle + algebraic properties.

Eq. 1 is the exact operation the paper's optimization *removes* on
selected iterations, so its correctness anchors the whole reproduction:
with s = 1 the combine degenerates to the conditional noise — the same
output the optimized (cond-only) path produces.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cfg_combine
from compile.kernels import ref


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize("shape", [
    (1, 4, 8, 8), (2, 4, 8, 8), (4, 4, 16, 16), (1, 4, 24, 24), (128,),
    (3, 5),  # non-multiple-of-128 total => tile clamping path
])
@pytest.mark.parametrize("scale", [0.0, 1.0, 7.5, 9.6])
def test_matches_ref(shape, scale):
    rng = np.random.default_rng(hash((shape, scale)) % 2**32)
    u, c = _rand(rng, shape), _rand(rng, shape)
    out = cfg_combine(u, c, scale)
    exp = ref.cfg_combine_ref(u, c, scale)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 700),
    scale=st.floats(-2.0, 20.0, allow_nan=False),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_hypothesis(n, scale, seed):
    rng = np.random.default_rng(seed)
    u, c = _rand(rng, (n,)), _rand(rng, (n,))
    out = cfg_combine(u, c, scale)
    exp = ref.cfg_combine_ref(u, c, scale)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-5)


def test_scale_one_returns_conditional():
    """s=1: eps_hat == eps_c — the identity behind the paper's 'optimized
    steps equal full steps when guidance is neutral' sanity check."""
    rng = np.random.default_rng(0)
    u, c = _rand(rng, (2, 4, 8, 8)), _rand(rng, (2, 4, 8, 8))
    np.testing.assert_allclose(cfg_combine(u, c, 1.0), c, rtol=1e-6,
                               atol=1e-6)


def test_scale_zero_returns_unconditional():
    rng = np.random.default_rng(1)
    u, c = _rand(rng, (1, 4, 8, 8)), _rand(rng, (1, 4, 8, 8))
    np.testing.assert_allclose(cfg_combine(u, c, 0.0), u, rtol=1e-6,
                               atol=1e-6)


def test_linearity_in_scale():
    """eps_hat(s) is affine in s: midpoint identity."""
    rng = np.random.default_rng(2)
    u, c = _rand(rng, (1, 4, 8, 8)), _rand(rng, (1, 4, 8, 8))
    a = np.asarray(cfg_combine(u, c, 2.0))
    b = np.asarray(cfg_combine(u, c, 8.0))
    mid = np.asarray(cfg_combine(u, c, 5.0))
    np.testing.assert_allclose((a + b) / 2, mid, rtol=1e-5, atol=1e-5)


def test_equal_inputs_fixed_point():
    """When eps_u == eps_c the guidance term vanishes for every s."""
    rng = np.random.default_rng(3)
    e = _rand(rng, (1, 4, 16, 16))
    for s in (0.0, 7.5, 100.0):
        np.testing.assert_allclose(cfg_combine(e, e, s), e, rtol=1e-6,
                                   atol=1e-6)
